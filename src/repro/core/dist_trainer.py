"""The unified distributed-training loop.

One loop runs every configuration the paper compares (and the ones it
proposes as future work): the strategy object owns *when and what* to
synchronize, the loop owns everything else — vmapped inner steps, loss
recording, eval hooks, history.  ``run_ddp`` / ``run_diloco`` /
``run_streaming_diloco`` remain as thin wrappers over this loop.

    trainer = DistTrainer(model.loss, opt_cfg, dcfg, DiLoCoSync())
    state = trainer.init(params)
    state, hist = trainer.run(state, data_fn, num_steps)

History keys: ``step`` / ``loss`` (every ``record_every``), ``sync_steps``
(full outer exchanges), ``frag_syncs`` (``(step, fragment)`` pairs),
``evals`` (``(step, eval_fn(global_params))`` pairs), ``step_seconds``
(median measured seconds per inner step — robust to jit-compile spikes;
feeds the comm simulator's calibration).

The hot path (chunked execution)
--------------------------------
DiLoCo's premise is that the H local steps dominate wall-clock while sync
is rare — so the device must never wait on Python between syncs.  The
default ``chunked=True`` loop makes that true:

* **chunk = steps to the next sync event.**  Each ``SyncRunner`` exposes
  ``next_event(step)`` — the next step whose ``after_step`` touches device
  state (an outer sync, a delayed apply, a straggler snapshot).  The loop
  ``lax.scan``s the inner step from the current step to exactly that
  boundary (further split by ``eval_every`` and ``num_steps``), so one
  device dispatch replaces ~H per-step dispatches.  For DiLoCo the chunk
  boundaries ARE the H boundaries; for streaming/pipelined schedules the
  fragment events fire at the same steps they would per-step.  Runners on
  per-worker event clocks (async gossip: worker i syncs every ``H + j_i``
  steps) report the MIN over workers' next boundaries, so a chunk ends
  whenever ANY worker is due — the contract is per-runner, not per-fleet.
* **one fetch per chunk.**  Per-step per-worker losses come back as one
  (T, K) device array fetched with a single ``device_get``; ``after_step``
  is then replayed per step on the host with fixed-order means of those
  rows (between events it is pure bookkeeping by contract, see
  ``SyncRunner``), so histories —
  ``step``/``loss``/``sync_steps``/``frag_syncs``/``evals``, plus any
  runner-defined keys such as gossip's ``gossip_syncs`` (lists are
  created on demand) — are bit-identical to the per-step loop's.
* **buffer donation.**  The chunk jit donates the state (params, momenta,
  and optimizer moments update in place on accelerators), as do the
  runners' outer-step jits.  ``run`` defensively copies the caller's
  state once at entry so the passed-in state object survives the run.
* **async prefetch.**  ``prefetch=N`` sources batches from a background
  ``repro.data.pipeline.Prefetcher`` that assembles batches up to N steps
  ahead (one stacked ``device_put`` per chunk at take time), overlapping
  host data work with device compute.  At every chunk boundary the loop
  additionally ``prime``s the next chunk, so its host stack +
  ``device_put`` overlap the outer-sync jit dispatched at the boundary
  instead of serializing behind it (``take`` falls back losslessly if a
  runner shifts the predicted bounds).
* ``step_seconds`` is each chunk's wall-clock divided by its length
  (median over chunks), preserving the comm-simulator calibration
  contract.

``chunked=False`` keeps the original per-step loop — the reference the
bit-exactness tests (and ``benchmarks/train_bench.py``) compare against.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs.base import DiLoCoConfig, OptimizerConfig
from repro.core.diloco import DiLoCoState
from repro.core.faults import FaultSchedule, FleetTracker, SimulatedCrash
from repro.core.streaming import StreamingDiLoCoTrainer
from repro.core.sync import SyncStrategy

# the loop's single deliberate device->host read per chunk — module-level so
# the one-fetch guard test can count calls
_fetch = jax.device_get

# CPU backends ignore donation for some buffers; the advisory warning would
# fire once per compiled chunk length.  Applied via catch_warnings inside
# run() only — a library import must not rewrite global warning filters.
_DONATION_WARNING = "Some donated buffers were not usable"


def _bind(strategy: SyncStrategy, engine, params, donate: bool):
    """strategy.bind with the ``donate`` flag, tolerating pre-existing
    custom strategies whose bind() lacks the parameter."""
    import inspect
    try:
        has_donate = "donate" in inspect.signature(strategy.bind).parameters
    except (TypeError, ValueError):
        has_donate = False
    return (strategy.bind(engine, params, donate=donate) if has_donate
            else strategy.bind(engine, params))


def _host_mean(row: np.ndarray) -> float:
    """Worker-mean of a fetched (K,) loss row, in a FIXED summation order.

    Both loops record means of the RAW per-worker losses their jits
    output; reducing on device would let XLA pick a different reduce
    association per program (eager op vs scan body — a 1-ulp wobble that
    breaks chunked-vs-per-step bit-exactness and, through ``AdaptiveH``'s
    loss window, could even flip a sync decision).  Host IEEE f32 adds in
    index order are deterministic everywhere.
    """
    acc = row[0]
    for x in row[1:]:
        acc = acc + x
    return float(acc / row.dtype.type(len(row)))


def _host_mean_live(row: np.ndarray, live) -> float:
    """``_host_mean`` over only the live workers' loss entries (dead rows
    carry frozen params whose losses are not part of the fleet's trajectory).
    Same fixed index-order summation."""
    idx = [w for w, l in enumerate(live) if l]
    if not idx:
        return float("nan")
    acc = row[idx[0]]
    for w in idx[1:]:
        acc = acc + row[w]
    return float(acc / row.dtype.type(len(idx)))


def _history_from_json(v):
    """JSON round-trips tuples as lists; restore the tuples history
    consumers (and the resume bit-exactness tests) expect."""
    if isinstance(v, list):
        return tuple(_history_from_json(x) for x in v)
    return v


@dataclasses.dataclass(frozen=True)
class DistTrainer:
    """loss_fn(params, batch) -> (loss, metrics-dict); batches carry a
    leading (K, ...) worker dim (K=1 for DDP with the global batch)."""
    loss_fn: Callable
    opt_cfg: OptimizerConfig
    cfg: DiLoCoConfig
    strategy: SyncStrategy
    replicate_fn: Optional[Callable] = None

    # The compute engine: StreamingDiLoCoTrainer is the most general
    # DiLoCoTrainer (inner step + full outer step + fragment outer step);
    # strategies pick which pieces they drive.
    def engine(self) -> StreamingDiLoCoTrainer:
        return StreamingDiLoCoTrainer(
            self.loss_fn, self.opt_cfg, self.cfg, self.replicate_fn,
            num_fragments=getattr(self.strategy, "num_fragments", 4))

    def init(self, params) -> DiLoCoState:
        return self.engine().init(params)

    def run(self, state: DiLoCoState, data_fn, num_steps: int,
            record_every: int = 1, eval_fn: Optional[Callable] = None,
            eval_every: int = 0, *, chunked: bool = True,
            donate: bool = True, prefetch: int = 0,
            max_chunk: int = 128, faults: Optional[FaultSchedule] = None,
            min_quorum: int = 1, checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 0,
            resume: bool = False) -> Tuple[DiLoCoState, Dict]:
        """data_fn(step) -> per-worker-stacked batch pytree.

        ``chunked`` selects the scan-fused hot path (see module docstring);
        ``donate`` donates state buffers to the chunk/outer jits;
        ``prefetch`` > 0 assembles batches that many steps ahead on a
        background thread; ``max_chunk`` caps the scanned chunk length —
        ending a chunk early is always safe (between events ``after_step``
        is pure bookkeeping), and the cap bounds the on-device footprint
        of the stacked chunk batches for event-free strategies like DDP
        (0 = only events/evals/num_steps bound it; the default covers the
        paper's H=100 rounds in one chunk).

        Fault tolerance: ``faults`` scripts per-worker crash/rejoin/slow/
        drop/corrupt events and process-level kills (``repro.core.faults``);
        rounds proceed with the surviving subset while at least
        ``min_quorum`` workers contribute, and are skipped (workers keep
        training locally) below it.  ``checkpoint_dir`` + ``checkpoint_every``
        write crash-consistent outer-boundary checkpoints; ``resume=True``
        restores the latest one (state, runner extras, history, data cursor)
        and continues bit-exactly vs an uninterrupted run.
        """
        if not chunked:
            if prefetch > 0:
                raise ValueError(
                    "prefetch requires the chunked loop (chunked=True): "
                    "the per-step reference loop assembles batches "
                    "synchronously and would silently ignore it")
            if (faults is not None and not faults.empty) or checkpoint_dir \
                    or resume:
                raise ValueError(
                    "fault injection / checkpointing / resume require the "
                    "chunked loop (chunked=True): the per-step reference "
                    "loop has no chunk boundaries to anchor them to")
            # donate/max_chunk don't apply either: the reference loop
            # never donates and has no chunks
            return self._run_per_step(state, data_fn, num_steps,
                                      record_every, eval_fn, eval_every)
        if resume and not checkpoint_dir:
            raise ValueError("resume=True requires checkpoint_dir")
        eng = self.engine()
        runner = _bind(self.strategy, eng, state.global_params, donate)
        inner_chunk = jax.jit(eng.inner_chunk,
                              donate_argnums=(0,) if donate else ())
        tracker = None
        inner_live = None
        if faults is not None and not faults.empty:
            faults.validate(self.cfg.num_workers)
            tracker = FleetTracker(faults, self.cfg.num_workers,
                                   min_quorum=min_quorum)
            if faults.worker_events():
                # binds the quorum jits; raises for runners that don't
                # support per-worker faults.  Kill-only schedules skip the
                # bind so the untouched jit programs stay bit-exact with a
                # fault-free run (XLA specializes per compiled module).
                runner.bind_faults(tracker)
                inner_live = jax.jit(
                    eng.inner_chunk_live,
                    donate_argnums=(0,) if donate else ())
        if donate:
            # the first chunk donates the caller's state buffers; copy once
            # so the object the caller passed in survives the run
            state = jax.tree.map(jnp.copy, state)

        restored_history: Dict[str, list] = {}
        start_step = 0
        if resume:
            from repro.checkpoint import (latest_run_checkpoint,
                                          load_run_checkpoint)
            manifest = latest_run_checkpoint(checkpoint_dir)
            if manifest is not None:
                template = runner.checkpoint_extras()
                extras_template = template[0] if template is not None else None
                state, extras = load_run_checkpoint(manifest, state,
                                                    extras_template)
                runner.load_extras(extras,
                                   manifest.get("extras_meta") or {})
                restored_history = manifest.get("history") or {}
                start_step = int(manifest["step"])
                if tracker is not None:
                    tracker.catch_up(start_step)

        from repro.data.pipeline import Prefetcher, stack_batches
        source = (Prefetcher(data_fn, num_steps, depth=prefetch,
                             start=start_step)
                  if prefetch > 0 else None)

        history: Dict[str, list] = {"step": [], "loss": [], "sync_steps": [],
                                    "frag_syncs": [], "evals": []}
        for key, vals in restored_history.items():
            history[key] = [_history_from_json(v) for v in vals]

        def record(recs):
            for key, val in recs:
                # runners may emit novel keys (e.g. gossip_syncs): history
                # lists are created on demand
                history.setdefault(key, []).append(val)

        chunk_step_seconds = []
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=_DONATION_WARNING)
            def chunk_end(step: int) -> int:
                end = num_steps - 1
                event = runner.next_event(step)
                if event is not None:
                    end = min(end, max(event, step))
                if eval_fn is not None and eval_every:
                    # an eval landing mid-chunk splits the chunk (the
                    # eval must see the state at exactly that step)
                    end = min(end, (step // eval_every + 1) * eval_every - 1)
                if max_chunk:
                    end = min(end, step + max_chunk - 1)
                if checkpoint_dir and checkpoint_every:
                    # a checkpoint landing mid-chunk splits the chunk (the
                    # snapshot must see the state at exactly that boundary)
                    end = min(end, (step // checkpoint_every + 1)
                              * checkpoint_every - 1)
                if tracker is not None:
                    lim = tracker.chunk_limit(step)
                    if lim is not None:
                        end = min(end, max(lim, step))
                return end

            try:
                step = start_step
                t_prev = time.time()
                pending_ckpt = False
                while step < num_steps:
                    live = None
                    if tracker is not None:
                        live, recs = tracker.begin_chunk(step)
                        record(recs)
                    end = chunk_end(step)
                    T = end - step + 1
                    batches = (source.take(step, T) if source is not None
                               else stack_batches([data_fn(s)
                                                   for s in
                                                   range(step, end + 1)]))
                    if inner_live is not None and not all(live):
                        # dead rows freeze (params + opt pass through); the
                        # all-live path keeps the original jit program so
                        # fault-free stretches stay bit-exact with it
                        state, losses = inner_live(
                            state, batches,
                            jnp.asarray(live, jnp.bool_))
                    else:
                        state, losses = inner_chunk(state, batches)
                    losses_host = _fetch(losses)    # ONE fetch per chunk
                    for i in range(T):
                        s = step + i
                        loss_mean = (_host_mean(losses_host[i])
                                     if live is None or all(live)
                                     else _host_mean_live(losses_host[i],
                                                          live))
                        if s % record_every == 0:
                            history["step"].append(s)
                            history["loss"].append(loss_mean)
                        new_state, recs = runner.after_step(state, s,
                                                            loss_mean)
                        if new_state is not state and i != T - 1:
                            raise RuntimeError(
                                f"sync runner replaced the state at step "
                                f"{s}, mid-chunk (chunk ends at {end}): "
                                f"next_event() must report every step "
                                f"whose after_step touches device state — "
                                f"e.g. an HSchedule that fires before "
                                f"since_sync reaches current_h violates "
                                f"the chunked contract; run with "
                                f"chunked=False for such schedules")
                        state = new_state
                        record(recs)
                    if source is not None and end + 1 < num_steps:
                        # the replay above just dispatched any outer sync
                        # asynchronously; start assembling the NEXT chunk's
                        # batches now so the stack + device_put overlap the
                        # sync instead of serializing behind it at the top
                        # of the loop.  next_event is accurate here (the
                        # runner replayed through ``end``), so the primed
                        # bounds match the next take(); if a custom runner
                        # shifts them anyway, take() falls back losslessly.
                        source.prime(end + 1, chunk_end(end + 1) - end)
                    t_now = time.time()
                    chunk_step_seconds.append((t_now - t_prev) / T)
                    t_prev = t_now
                    if checkpoint_dir and checkpoint_every and (
                            pending_ckpt
                            or (end + 1) % checkpoint_every == 0):
                        extras = runner.checkpoint_extras()
                        if extras is None:
                            # runner mid-round: its in-flight device state
                            # isn't serializable — defer to the next clean
                            # chunk boundary
                            pending_ckpt = True
                        else:
                            pending_ckpt = False
                            from repro.checkpoint import save_run_checkpoint
                            arrays, extras_meta = extras
                            save_run_checkpoint(
                                checkpoint_dir, end + 1, _fetch(state),
                                extras_arrays=_fetch(arrays),
                                extras_meta=extras_meta,
                                history=history,
                                meta={"num_steps": num_steps})
                            t_prev = time.time()  # ckpt IO != step time
                    if tracker is not None and tracker.kill_at(end):
                        # scripted process death: any due checkpoint was
                        # just written; the finally below closes the source
                        # and finalize() never runs — exactly a crash
                        raise SimulatedCrash(
                            f"scripted kill after step {end}")
                    if (eval_fn is not None and eval_every
                            and (end + 1) % eval_every == 0):
                        state = runner.refresh(state)
                        history["evals"].append(
                            (end, eval_fn(state.global_params)))
                        t_prev = time.time()    # eval time != step time
                    step = end + 1
            finally:
                if source is not None:
                    source.close()
            state, recs = runner.finalize(state, num_steps)
            record(recs)
        # measured steady-state seconds/step: median over per-chunk means is
        # robust to the jit-compile spikes on first-seen chunk lengths
        history["step_seconds"] = sorted(chunk_step_seconds)[
            len(chunk_step_seconds) // 2] if chunk_step_seconds else 0.0
        return state, history

    def _run_per_step(self, state: DiLoCoState, data_fn, num_steps: int,
                      record_every: int = 1,
                      eval_fn: Optional[Callable] = None,
                      eval_every: int = 0) -> Tuple[DiLoCoState, Dict]:
        """The original per-step loop: one dispatch + one host sync per
        inner step.  Kept as the reference for the chunked path's
        bit-exactness tests and as the benchmark baseline.  Binds with
        donate=False — the pre-chunking loop never donated, and an
        eval_fn here may retain references into the live state."""
        eng = self.engine()
        runner = _bind(self.strategy, eng, state.global_params, False)
        inner_jit = jax.jit(eng.inner_step)
        history: Dict[str, list] = {"step": [], "loss": [], "sync_steps": [],
                                    "frag_syncs": [], "evals": []}

        def record(recs):
            for key, val in recs:
                # runners may emit novel keys (e.g. gossip_syncs): history
                # lists are created on demand
                history.setdefault(key, []).append(val)

        step_durations = []
        t_prev = time.time()
        for step in range(num_steps):
            state, loss, _ = inner_jit(state, data_fn(step))
            # host-side fixed-order mean of the raw per-worker losses —
            # bit-identical to the chunked loop's recording (_host_mean)
            loss_mean = _host_mean(_fetch(loss))
            if step % record_every == 0:
                history["step"].append(step)
                history["loss"].append(loss_mean)
            state, recs = runner.after_step(state, step, loss_mean)
            record(recs)
            # loss_mean + after_step forced this step (and any sync it
            # triggered) to complete before the clock is read
            t_now = time.time()
            step_durations.append(t_now - t_prev)
            t_prev = t_now
            if eval_fn is not None and eval_every and (step + 1) % eval_every == 0:
                state = runner.refresh(state)
                history["evals"].append((step, eval_fn(state.global_params)))
        state, recs = runner.finalize(state, num_steps)
        record(recs)
        # measured steady-state seconds/step: the median is robust to the
        # one-off jit-compile spikes (inner step at 0, outer step at the
        # first sync) that a mean over a short run would smear in
        history["step_seconds"] = sorted(step_durations)[
            len(step_durations) // 2] if step_durations else 0.0
        return state, history

    # -- communication accounting -------------------------------------------
    def payload_schedule(self, params, num_steps: int) -> list:
        """The strategy's payload footprint for ``num_steps`` inner steps —
        feed to ``repro.launch.comm_sim.simulate_schedule`` for modeled
        wall-clock."""
        n = sum(int(x.size) for x in jax.tree.leaves(params))
        return self.strategy.payload_schedule(n, num_steps, self.cfg)
