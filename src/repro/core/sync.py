"""Pluggable synchronization strategies for the unified ``DistTrainer`` loop.

The paper frames DiLoCo as a lightweight wrapper over nanochat's training
loop; this module makes that literal.  One host-side loop (``DistTrainer``
in ``repro.core.dist_trainer``) drives vmapped inner steps, and a
``SyncStrategy`` decides everything cross-worker:

* ``DDPSync``        — synchronize every step (K=1 + the global batch, the
                       paper's "Standard DDP" baseline),
* ``CompressedDDPSync`` — K workers exchanging their per-step parameter
                       updates through a lossy codec (int8/fp8) with
                       error-feedback residuals held by the runner; with a
                       lossless codec it IS per-step delta-averaged DDP,

* ``DiLoCoSync``     — full delta exchange every H steps (paper §2.2),
                       pluggable H schedule incl. ``AdaptiveH``,
* ``StreamingSync``  — fragment-wise staggered exchange every H/F steps
                       (Streaming DiLoCo, arXiv:2501.18512),
* ``OverlappedSync`` — Streaming DiLoCo's "overlapping communication":
                       the delta is captured at step *t* but the outer
                       update lands at *t+delay*, hiding the exchange
                       behind inner compute; per-worker H jitter emulates
                       asynchronous / straggler workers (the delta of a
                       straggler reflects fewer inner steps),
* ``PipelinedSync``  — the DiLoCoX shape (arXiv:2506.21263): ONE fragment
                       per outer round, captured at the boundary and
                       applied ``delay`` steps later.  Each parameter syncs
                       every F·H steps, so combined with the int8 codec the
                       boundary traffic drops another ~4F× below f32
                       DiLoCo at unchanged compute.

A strategy has two faces:

1. ``bind(engine, params) -> SyncRunner`` — a per-run state machine the
   training loop calls after every inner step;
2. ``payload_schedule(n_params, num_steps, cfg) -> [SyncEvent]`` — the pure
   communication footprint, consumed by the event-driven wall-clock
   simulator in ``repro.launch.comm_sim``.

Transport-layer contract (see ``repro.core.transport`` for the wire format)
---------------------------------------------------------------------------
Strategies never ship raw f32 pytrees.  Every exchange goes delta ->
``Codec.encode`` -> ``OuterPayload`` (wire-dtype data + per-tensor scales)
-> ``Transport.ship`` (the replicate hop, narrow dtype on the wire) ->
``Codec.decode`` -> averaged f32 — that path is
``outer_opt.exchange_and_average``, which every engine outer step calls.
Runners own the codec's per-worker error-feedback residual (created by
``engine.init_residual``; None for lossless codecs), thread it through
each ``*_ef`` outer step, and the payload schedules report bytes in the
codec's wire width with the codec name stamped on each ``SyncEvent`` so
the simulator can account bytes per codec.

Adding a new sync variant means implementing those two methods (~50 lines),
not writing a new training loop.
"""
from __future__ import annotations

import dataclasses
import random as _pyrandom
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig
from repro.core import outer_opt
from repro.core.schedule import FixedH, HSchedule
from repro.core.transport import make_codec

# history records a runner can emit: (history_key, value) pairs
Records = List[Tuple[str, Any]]


@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """One cross-worker payload on the slow (inter-pod) boundary.

    ``step`` is the inner step after which the payload leaves the worker;
    ``apply_step`` is the step by which the result must have landed (equal
    to ``step`` for blocking strategies, later for overlapped ones — the
    gap is the window the transfer may hide behind compute).  ``codec``
    names the wire codec so the simulator can account bytes per codec.
    """
    step: int
    bytes_per_worker: int
    kind: str                   # "grads" | "delta" | "fragment"
    apply_step: int
    fragment: int = -1
    codec: str = "f32"


class SyncRunner:
    """Per-run host-side state machine created by ``SyncStrategy.bind``.

    The chunked ``DistTrainer`` loop (``core.dist_trainer``) scans inner
    steps on device until the runner's next *event* — a step whose
    ``after_step`` touches device state (sync, snapshot, delayed apply).
    Between events ``after_step`` must be pure host bookkeeping (counters,
    loss windows) that ignores ``state``, because under chunking it is
    called with the post-chunk state for every step of the chunk.  When
    bound with ``donate=True`` the runner jits donate their
    state/residual arguments (params and momenta update in place), so any
    snapshot a runner keeps across steps must be a fresh buffer, never an
    alias of ``state`` leaves.
    """

    def after_step(self, state, step: int, loss: float):
        """Called after every inner step; returns (state, records)."""
        return state, []

    def next_event(self, step: int) -> Optional[int]:
        """First step >= ``step`` whose ``after_step`` may touch device
        state; ``None`` = no event before the run ends.  The base class is
        maximally conservative (every step is an event), which degrades
        the chunked loop to per-step execution."""
        return step

    def refresh(self, state):
        """Bring ``global_params`` up to date for an observer (eval hook);
        identity for strategies that maintain it on every sync."""
        return state

    def finalize(self, state, num_steps: int):
        """Called once after the last step; returns (state, records)."""
        return state, []


class SyncStrategy:
    name = "base"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        """Create the per-run state machine.  ``donate`` controls whether
        the runner's outer-step jits donate their state/residual
        arguments (``DistTrainer.run`` threads its own ``donate`` flag
        here; the per-step reference loop passes False to keep the
        pre-chunking no-donation behaviour)."""
        raise NotImplementedError

    def payload_schedule(self, n_params: int, num_steps: int,
                         cfg: DiLoCoConfig) -> List[SyncEvent]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# DDP — synchronize every step
# ---------------------------------------------------------------------------

class _DDPRunner(SyncRunner):
    def after_step(self, state, step, loss):
        # K=1 + global batch: the worker IS the global model, synchronized
        # by construction — nothing to exchange, just record the cadence.
        return state, [("sync_steps", step)]

    def next_event(self, step):
        return None     # never touches device state between refreshes

    def refresh(self, state):
        gp = jax.tree.map(lambda w: w[0], state.worker_params)
        return state._replace(global_params=gp)

    def finalize(self, state, num_steps):
        return self.refresh(state), []


@dataclasses.dataclass(frozen=True)
class DDPSync(SyncStrategy):
    """Fully synchronous baseline: fp32 gradient all-reduce every step."""
    name = "ddp"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        if engine.cfg.num_workers != 1:
            raise ValueError(
                "DDPSync is the K=1 + global-batch baseline; "
                f"got num_workers={engine.cfg.num_workers}.  Use DiLoCoSync "
                "with H=1 for per-step delta averaging across workers.")
        return _DDPRunner()

    def payload_schedule(self, n_params, num_steps, cfg):
        b = 4 * n_params  # fp32 grads, every step, blocking
        return [SyncEvent(step=s, bytes_per_worker=b, kind="grads",
                          apply_step=s) for s in range(num_steps)]


# ---------------------------------------------------------------------------
# Compressed DDP — per-step update exchange through a lossy codec
# ---------------------------------------------------------------------------

def compressed_ddp_config(cfg: DiLoCoConfig) -> DiLoCoConfig:
    """Fold ``cfg.grad_compress`` into a per-step delta-exchange config.

    H=1 with an identity outer update (lr=1, no momentum) makes the outer
    step exactly "average the workers' one-step parameter updates" — for
    SGD inner optimizers that is literally gradient averaging, and for
    AdamW/Muon it is DDP on the *effective update*, which is the quantity
    gradient-compression schemes actually care about.  The codec (and its
    error-feedback residual, held by the sync runner) then rides the same
    transport stack as every DiLoCo variant.
    """
    codec = cfg.grad_compress if cfg.grad_compress not in ("", "none") \
        else "float32"
    return dataclasses.replace(
        cfg, strategy="ddp_compressed", h_inner_steps=1, outer_lr=1.0,
        outer_momentum=0.0, nesterov=False, delta_dtype=codec)


@dataclasses.dataclass(frozen=True)
class CompressedDDPSync(SyncStrategy):
    """DDP with compressed per-step exchange: K workers average their
    one-step parameter updates through the configured codec every step.
    Build the config with ``compressed_ddp_config`` — the identity outer
    update (H=1, lr=1, mu=0) is what makes this DDP rather than DiLoCo;
    ``bind`` rejects configs that would silently change the semantics.
    Lossless codec => bitwise per-step delta-averaged DDP; int8/fp8 adds
    the quantizer + error feedback, the second anchor the benchmarks
    compare DiLoCo's bandwidth savings against."""
    name = "ddp_compressed"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        cfg = engine.cfg
        if cfg.outer_lr != 1.0 or cfg.outer_momentum != 0.0 or cfg.nesterov:
            raise ValueError(
                "CompressedDDPSync needs the identity outer update "
                "(outer_lr=1, outer_momentum=0, nesterov=False) — build the "
                "config with sync.compressed_ddp_config(); got "
                f"lr={cfg.outer_lr} mu={cfg.outer_momentum} "
                f"nesterov={cfg.nesterov}")
        return _DiLoCoRunner(engine, params, FixedH(1), donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        codec = make_codec(cfg.delta_dtype if cfg.strategy == "ddp_compressed"
                           else (cfg.grad_compress
                                 if cfg.grad_compress not in ("", "none")
                                 else "float32"))
        b = codec.schedule_bytes(n_params)
        return [SyncEvent(step=s, bytes_per_worker=b, kind="grads",
                          apply_step=s, codec=codec.name)
                for s in range(num_steps)]


# ---------------------------------------------------------------------------
# DiLoCo — full delta exchange every H steps
# ---------------------------------------------------------------------------

class _DiLoCoRunner(SyncRunner):
    def __init__(self, engine, params, hs: HSchedule, donate: bool = True):
        self.hs = hs
        self.since = 0
        self.residual = engine.init_residual(params)
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def _sync(self, state):
        state, self.residual = self._outer(state, self.residual)
        return state

    def after_step(self, state, step, loss):
        self.since += 1
        if self.hs.should_sync(step, self.since, loss):
            self.since = 0
            return self._sync(state), [("sync_steps", step)]
        return state, []

    def finalize(self, state, num_steps):
        if self.since:  # trailing sync so global_params reflect all work
            return self._sync(state), [("sync_steps", num_steps - 1)]
        return state, []

    def next_event(self, step):
        # syncs fire when since_sync reaches the schedule's current H, and
        # every supported HSchedule only changes H at a sync (AdaptiveH's
        # loss window is fed per step by after_step, but its slope check
        # runs at the boundary), so the next boundary is deterministic
        try:
            h = int(self.hs.current_h)
        except Exception:       # exotic schedule: degrade to per-step
            return step
        return step + max(h - self.since, 1) - 1


@dataclasses.dataclass(frozen=True)
class DiLoCoSync(SyncStrategy):
    """Paper §2.2: average parameter deltas + outer Nesterov SGD every H.

    ``h`` overrides the config's ``h_inner_steps``; ``h_schedule`` plugs in
    any ``HSchedule`` (e.g. ``AdaptiveH``) instead of fixed H.
    """
    name = "diloco"
    h: Optional[int] = None
    h_schedule: Optional[HSchedule] = None

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        hs = self.h_schedule or FixedH(self.h or engine.cfg.h_inner_steps)
        return _DiLoCoRunner(engine, params, hs, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = codec.schedule_bytes(n_params)
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s, codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Streaming DiLoCo — one fragment every H/F steps, staggered
# ---------------------------------------------------------------------------

class _StreamingRunner(SyncRunner):
    def __init__(self, engine, params, donate: bool = True):
        from repro.core.streaming import fragment_masks
        self.F = engine.num_fragments
        self.masks = fragment_masks(params, self.F)
        self.period = engine.fragment_schedule()
        self.residual = engine.init_residual(params)
        # donate state + residual (arg 1 is the reused fragment mask)
        self._frag = jax.jit(engine.outer_step_fragment_ef,
                             donate_argnums=(0, 2) if donate else ())

    def after_step(self, state, step, loss):
        if (step + 1) % self.period == 0:
            f = ((step + 1) // self.period - 1) % self.F
            state, self.residual = self._frag(state, self.masks[f],
                                              self.residual)
            return state, [("frag_syncs", (step, f))]
        return state, []

    def next_event(self, step):
        # fragment boundaries: every step s with (s + 1) % period == 0
        return (step // self.period + 1) * self.period - 1


@dataclasses.dataclass(frozen=True)
class StreamingSync(SyncStrategy):
    """Fragment-wise staggered sync (arXiv:2501.18512): every parameter
    still syncs each H, but instantaneous bandwidth demand drops F×."""
    name = "streaming"
    num_fragments: int = 4

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        return _StreamingRunner(engine, params, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = cfg.h_inner_steps
        period = max(h // self.num_fragments, 1)
        codec = make_codec(cfg.delta_dtype)
        b = codec.schedule_bytes(n_params // self.num_fragments)
        return [SyncEvent(step=s, bytes_per_worker=b, kind="fragment",
                          # a fragment may stream until its next slot
                          apply_step=s + period - 1,
                          fragment=((s + 1) // period - 1) % self.num_fragments,
                          codec=codec.name)
                for s in range(period - 1, num_steps, period)]


# ---------------------------------------------------------------------------
# Overlapped DiLoCo — delta captured at t, outer update applied at t+delay
# ---------------------------------------------------------------------------

class _OverlappedRunner(SyncRunner):
    """Captures per-worker delta snapshots (with straggler jitter) at each
    round boundary and applies the outer update ``delay`` steps later.
    Inner progress made during the communication window is carried forward:
    at apply time worker i becomes  new_global + (w_now_i − snap_i).
    With delay=0 and jitter=0 this is exactly ``DiLoCoSync``."""

    def __init__(self, engine, params, h: int, delay: int, jitter: int,
                 seed: int, donate: bool = True):
        if not 0 <= delay < h:
            raise ValueError(f"need 0 <= delay < h, got delay={delay} h={h}")
        if jitter < 0 or jitter + delay >= h:
            raise ValueError(
                f"need jitter + delay < h so every snapshot lands after the "
                f"previous apply, got jitter={jitter} delay={delay} h={h}")
        self.engine = engine
        self.h, self.delay, self.jitter = h, delay, jitter
        self.k = engine.cfg.num_workers
        self.rng = _pyrandom.Random(seed)
        self.round_end = h - 1
        self.snap_steps = self._draw_snap_steps()
        self.buf = None                 # snapshot buffer being filled
        self.pending = None             # frozen snapshot awaiting apply
        self.pending_apply = -1
        self.residual = engine.init_residual(params)
        self._snap_row = jax.jit(
            lambda buf, wp, i: jax.tree.map(
                lambda b, w: b.at[i].set(w[i]), buf, wp))
        # donate state + residual; the snapshot is NOT donated — there is
        # no second (K, ...) output left to reuse its buffer for (the
        # worker-param output aliases the donated state's)
        self._apply = jax.jit(self._apply_impl,
                              donate_argnums=(0, 2) if donate else ())
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def _draw_snap_steps(self) -> Dict[int, int]:
        """Worker i's delta leaves jitter_i steps before the boundary — a
        straggler's contribution reflects fewer inner steps."""
        return {i: self.round_end
                - (self.rng.randint(0, self.jitter) if self.jitter else 0)
                for i in range(self.k)}

    def _apply_impl(self, state, snap, residual):
        cfg = self.engine.cfg
        delta = jax.tree.map(
            lambda s, g: s.astype(jnp.float32) - g.astype(jnp.float32)[None],
            snap, state.global_params)
        avg, new_res = outer_opt.exchange_and_average(
            delta, cfg, self.engine.replicate_fn, residual=residual)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, cfg)
        # carry forward the inner progress made while the exchange was in
        # flight: worker = synced base + (current − snapshot)
        new_wp = jax.tree.map(
            lambda w, s, ng: (ng.astype(jnp.float32)[None]
                              + (w.astype(jnp.float32) - s.astype(jnp.float32))
                              ).astype(w.dtype),
            state.worker_params, snap, new_global)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, outer=new_outer), new_res

    def after_step(self, state, step, loss):
        records: Records = []
        due = [i for i, s in self.snap_steps.items() if s == step]
        if due:
            if self.jitter == 0:
                # every worker snaps at the boundary: one whole-tree copy
                # (fresh buffers — the donated chunk/apply jits recycle
                # the state's, so the snapshot must never alias them)
                self.buf = jax.tree.map(jnp.copy, state.worker_params)
            else:
                if self.buf is None:
                    self.buf = state.worker_params
                for i in due:
                    # .at[].set yields fresh buffers, so the finished buf
                    # never aliases donated state leaves either
                    self.buf = self._snap_row(self.buf, state.worker_params,
                                              jnp.int32(i))
        if step == self.round_end:
            # every worker's snap step is <= round_end and was processed
            # above, so buf is always populated here
            self.pending = self.buf
            self.pending_apply = step + self.delay
            self.buf = None
            self.round_end += self.h
            self.snap_steps = self._draw_snap_steps()
        if self.pending is not None and step >= self.pending_apply:
            state, self.residual = self._apply(state, self.pending,
                                               self.residual)
            self.pending = None
            records.append(("sync_steps", step))
        return state, records

    def next_event(self, step):
        cands = [s for s in self.snap_steps.values() if s >= step]
        cands.append(self.round_end)
        if self.pending is not None:
            cands.append(max(self.pending_apply, step))
        return min(cands)

    def finalize(self, state, num_steps):
        records: Records = []
        if self.pending is not None:  # flush the in-flight round
            state, self.residual = self._apply(state, self.pending,
                                               self.residual)
            self.pending = None
            records.append(("sync_steps", num_steps - 1))
        if num_steps % self.h:        # trailing partial round: full sync
            state, self.residual = self._outer(state, self.residual)
            records.append(("sync_steps", num_steps - 1))
        return state, records


@dataclasses.dataclass(frozen=True)
class OverlappedSync(SyncStrategy):
    """Streaming DiLoCo's overlapping communication for the *full* delta:
    capture at t, apply at t+delay, with per-worker straggler jitter.

    ``seed`` makes the jitter draws reproducible; ``make_strategy`` threads
    ``DiLoCoConfig.sync_seed`` here."""
    name = "overlapped"
    h: Optional[int] = None
    delay: int = 0
    jitter: int = 0
    seed: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        return _OverlappedRunner(engine, params, h, self.delay, self.jitter,
                                 self.seed, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = codec.schedule_bytes(n_params)
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s + self.delay, codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Pipelined (DiLoCoX) — ONE quantized fragment per round, delayed apply
# ---------------------------------------------------------------------------

class _PipelinedRunner(SyncRunner):
    """One fragment per outer round: at each H boundary the round's
    fragment (round mod F) is snapshotted, its encoded delta crosses the
    boundary while inner compute continues, and the outer update lands
    ``delay`` steps later.  Worker progress made in flight is carried
    forward on the fragment slots (like ``_OverlappedRunner``); the other
    slots keep diverging until their round comes up.  With F=1, delay=0
    this is exactly ``DiLoCoSync``."""

    def __init__(self, engine, params, h: int, delay: int,
                 num_fragments: int, donate: bool = True):
        if not 0 <= delay < h:
            raise ValueError(f"need 0 <= delay < h, got delay={delay} h={h}")
        from repro.core.streaming import fragment_masks
        self.engine = engine
        self.h, self.delay, self.F = h, delay, num_fragments
        self.masks = fragment_masks(params, num_fragments)
        self.residual = engine.init_residual(params)
        self.round = 0
        self.pending = None             # (snapshot, fragment) awaiting apply
        self.pending_apply = -1
        self._apply = jax.jit(self._apply_impl, static_argnames=("frag",),
                              donate_argnums=(0, 2) if donate else ())
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def _apply_impl(self, state, snap, residual, *, frag: int):
        cfg = self.engine.cfg
        mask = self.masks[frag]
        delta = jax.tree.map(
            lambda s, g, m: (s.astype(jnp.float32)
                             - g.astype(jnp.float32)[None]) * m[None],
            snap, state.global_params, mask)
        res_in = residual if residual is None else jax.tree.map(
            lambda r, m: r * m[None], residual, mask)
        avg, new_res = outer_opt.exchange_and_average(
            delta, cfg, self.engine.replicate_fn, residual=res_in,
            kind="fragment", fragment=frag)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, cfg)
        new_global = jax.tree.map(
            lambda ng, g, m: jnp.where(m, ng, g),
            new_global, state.global_params, mask)
        # fragment slots: synced base + progress made while in flight;
        # other slots untouched
        new_wp = jax.tree.map(
            lambda w, s, ng, m: jnp.where(
                m[None],
                (ng.astype(jnp.float32)[None]
                 + (w.astype(jnp.float32) - s.astype(jnp.float32))
                 ).astype(w.dtype),
                w),
            state.worker_params, snap, new_global, mask)
        if residual is not None:
            new_res = jax.tree.map(
                lambda nr, r, m: jnp.where(m[None], nr, r), new_res,
                residual, mask)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, outer=new_outer), new_res

    def after_step(self, state, step, loss):
        records: Records = []
        if (step + 1) % self.h == 0:
            # copy, not alias: the chunked loop (and the donated apply)
            # consume the state's buffers while this snapshot is in flight
            self.pending = (jax.tree.map(jnp.copy, state.worker_params),
                            self.round % self.F)
            self.pending_apply = step + self.delay
            self.round += 1
        if self.pending is not None and step >= self.pending_apply:
            snap, frag = self.pending
            state, self.residual = self._apply(state, snap, self.residual,
                                               frag=frag)
            self.pending = None
            records.append(("frag_syncs", (step, frag)))
        return state, records

    def next_event(self, step):
        cands = [(step // self.h + 1) * self.h - 1]   # next round boundary
        if self.pending is not None:
            cands.append(max(self.pending_apply, step))
        return min(cands)

    def finalize(self, state, num_steps):
        records: Records = []
        if self.pending is not None:  # flush the in-flight fragment
            snap, frag = self.pending
            state, self.residual = self._apply(state, snap, self.residual,
                                               frag=frag)
            self.pending = None
            records.append(("frag_syncs", (num_steps - 1, frag)))
        if num_steps % self.h:        # trailing partial round: full sync
            state, self.residual = self._outer(state, self.residual)
            records.append(("sync_steps", num_steps - 1))
        return state, records


@dataclasses.dataclass(frozen=True)
class PipelinedSync(SyncStrategy):
    """DiLoCoX-style pipelined low-bandwidth sync (arXiv:2506.21263): one
    fragment per outer round, overlapped with compute via ``delay``.  Each
    parameter syncs every F·H steps — combine with the int8 codec for the
    compounded ~4F× boundary-byte reduction over f32 DiLoCo."""
    name = "pipelined"
    h: Optional[int] = None
    num_fragments: int = 4
    delay: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        return _PipelinedRunner(engine, params, h, self.delay,
                                self.num_fragments, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = codec.schedule_bytes(n_params // self.num_fragments)
        return [SyncEvent(step=s, bytes_per_worker=b, kind="fragment",
                          apply_step=s + self.delay,
                          fragment=((s + 1) // h - 1) % self.num_fragments,
                          codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Config-driven construction
# ---------------------------------------------------------------------------

STRATEGIES = ("ddp", "ddp_compressed", "diloco", "streaming", "overlapped",
              "pipelined")


def make_strategy(cfg: DiLoCoConfig, h_schedule: Optional[HSchedule] = None
                  ) -> SyncStrategy:
    """Build the strategy the ``DiLoCoConfig`` knobs describe."""
    if cfg.strategy == "ddp":
        return DDPSync()
    if cfg.strategy == "ddp_compressed":
        return CompressedDDPSync()
    if cfg.strategy == "diloco":
        return DiLoCoSync(h_schedule=h_schedule)
    if cfg.strategy == "streaming":
        return StreamingSync(num_fragments=cfg.num_fragments)
    if cfg.strategy == "overlapped":
        return OverlappedSync(delay=cfg.sync_delay, jitter=cfg.h_jitter,
                              seed=cfg.sync_seed)
    if cfg.strategy == "pipelined":
        return PipelinedSync(num_fragments=cfg.num_fragments,
                             delay=cfg.sync_delay)
    raise ValueError(f"unknown strategy {cfg.strategy!r}; "
                     f"expected one of {STRATEGIES}")
