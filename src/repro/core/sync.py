"""Pluggable synchronization strategies for the unified ``DistTrainer`` loop.

The paper frames DiLoCo as a lightweight wrapper over nanochat's training
loop; this module makes that literal.  One host-side loop (``DistTrainer``
in ``repro.core.dist_trainer``) drives vmapped inner steps, and a
``SyncStrategy`` decides everything cross-worker:

* ``DDPSync``        — synchronize every step (K=1 + the global batch, the
                       paper's "Standard DDP" baseline),
* ``CompressedDDPSync`` — K workers exchanging their per-step parameter
                       updates through a lossy codec (int8/fp8) with
                       error-feedback residuals held by the runner; with a
                       lossless codec it IS per-step delta-averaged DDP,

* ``DiLoCoSync``     — full delta exchange every H steps (paper §2.2),
                       pluggable H schedule incl. ``AdaptiveH``,
* ``StreamingSync``  — fragment-wise staggered exchange every H/F steps
                       (Streaming DiLoCo, arXiv:2501.18512),
* ``OverlappedSync`` — Streaming DiLoCo's "overlapping communication":
                       the delta is captured at step *t* but the outer
                       update lands at *t+delay*, hiding the exchange
                       behind inner compute; per-worker H jitter emulates
                       asynchronous / straggler workers (the delta of a
                       straggler reflects fewer inner steps),
* ``PipelinedSync``  — the DiLoCoX shape (arXiv:2506.21263): ONE fragment
                       per outer round, captured at the boundary and
                       applied ``delay`` steps later.  Each parameter syncs
                       every F·H steps, so combined with the int8 codec the
                       boundary traffic drops another ~4F× below f32
                       DiLoCo at unchanged compute,
* ``GossipSync``     — NoLoCo-style no-all-reduce averaging
                       (arXiv:2506.10911): each outer round every worker
                       averages its delta with ONE peer drawn from a
                       deterministic topology schedule (ring / random
                       matching / full), so per-worker sync traffic is
                       O(1) in fleet size.  Workers keep their own anchor
                       + outer momentum; K=2 (any pairing) and the full
                       topology are bit-exact ``DiLoCoSync``,
* ``AsyncGossipSync``— gossip where workers sync on their OWN step clocks
                       (per-worker period H+jitter_i) and the apply rule
                       drops or drift-reweights peer contributions staler
                       than ``staleness_bound`` inner steps.  With
                       jitter=0 and bound=0 it is bit-exact ``GossipSync``
                       (the synchronous barrier).

A strategy has two faces:

1. ``bind(engine, params) -> SyncRunner`` — a per-run state machine the
   training loop calls after every inner step;
2. ``payload_schedule(n_params, num_steps, cfg) -> [SyncEvent]`` — the pure
   communication footprint, consumed by the event-driven wall-clock
   simulator in ``repro.launch.comm_sim``.

The ``SyncRunner`` contract (what ``DistTrainer`` drives)
---------------------------------------------------------
* ``after_step(state, step, loss) -> (state, records)`` — called after
  EVERY inner step.  Between events it must be pure host bookkeeping that
  ignores ``state`` (under chunking it sees the post-chunk state for every
  step of the chunk); at an event it may run jitted device work and must
  return the replaced state.  ``records`` are ``(history_key, value)``
  pairs appended to the run history — any key is allowed, the trainer
  creates history lists on demand.
* ``next_event(step) -> Optional[int]`` — the first step >= ``step``
  whose ``after_step`` may touch device state.  The chunked loop scans
  inner steps to exactly that boundary in ONE device dispatch, so an
  under-reported event (firing mid-chunk) is a contract violation the
  trainer raises on.  ``None`` = no event before the run ends.
* ``refresh(state) -> state`` — bring ``global_params`` up to date for an
  observer (eval hook); identity for strategies that maintain it at every
  sync.
* ``finalize(state, num_steps) -> (state, records)`` — called once after
  the last step; flushes trailing partial rounds / in-flight applies so
  ``global_params`` reflects all work.
* donation (PR 4 rules): when bound with ``donate=True`` the runner's
  jits donate their state/residual/anchor arguments — call them as
  ``state, self.x = self._jit(state, self.x)`` so stale host references
  never outlive donated buffers, and any snapshot kept across steps must
  be a FRESH buffer (``jax.tree.map(jnp.copy, ...)``), never an alias of
  ``state`` leaves.

Per-worker byte accounting (``hop_bytes_per_worker``)
-----------------------------------------------------
``payload_schedule`` denominates ``SyncEvent.bytes_per_worker`` in bytes
each worker actually moves over ITS boundary link for one hop:

* codec'd delta exchange (DiLoCo family): per-worker scales make
  in-network reduction impossible, so the replicate hop is an all-GATHER
  — (K-1)·payload per worker, growing with fleet size;
* f32 DDP gradients are summable: bandwidth-optimal ring all-reduce,
  2·(K-1)/K·payload ≈ 2·payload;
* gossip: ONE peer payload per worker, flat in K (full topology is the
  gather again — it IS the DiLoCo mean).

Transport-layer contract (see ``repro.core.transport`` for the wire format)
---------------------------------------------------------------------------
Strategies never ship raw f32 pytrees.  Every exchange goes delta ->
``Codec.encode`` -> ``OuterPayload`` (wire-dtype data + per-tensor scales)
-> ``Transport.ship`` (the replicate hop, narrow dtype on the wire) ->
``Codec.decode`` -> averaged f32 — that path is
``outer_opt.exchange_and_average``, which every engine outer step calls.
Runners own the codec's per-worker error-feedback residual (created by
``engine.init_residual``; None for lossless codecs), thread it through
each ``*_ef`` outer step, and the payload schedules report bytes in the
codec's wire width with the codec name stamped on each ``SyncEvent`` so
the simulator can account bytes per codec.

Adding a new sync variant means implementing those two methods (~50 lines),
not writing a new training loop.
"""
from __future__ import annotations

import dataclasses
import functools
import random as _pyrandom
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DiLoCoConfig
from repro.core import outer_opt
from repro.core.schedule import FixedH, HSchedule
from repro.core.transport import make_codec

# history records a runner can emit: (history_key, value) pairs
Records = List[Tuple[str, Any]]

# the runners' deliberate device->host read for rejoin drift metrics (one
# tiny scalar pair per rejoin EVENT, never per step) — module-level so the
# host-sync lint pass recognizes the documented fetch point
_fetch = jax.device_get


@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """One cross-worker payload on the slow (inter-pod) boundary.

    ``step`` is the inner step after which the payload leaves the worker;
    ``apply_step`` is the step by which the result must have landed (equal
    to ``step`` for blocking strategies, later for overlapped ones — the
    gap is the window the transfer may hide behind compute).  ``codec``
    names the wire codec so the simulator can account bytes per codec.
    """
    step: int
    bytes_per_worker: int
    kind: str                   # "grads" | "delta" | "fragment"
    apply_step: int
    fragment: int = -1
    codec: str = "f32"


def hop_bytes_per_worker(payload_bytes: int, k: int, collective: str) -> int:
    """Bytes ONE worker moves over its boundary link for one sync hop.

    ``collective`` names what the hop actually is on the wire:

    * ``"gather"`` — codec'd payloads carry per-worker scales, so rows
      cannot be summed in-network; every worker receives the other K-1
      rows: (K-1)·payload (K=1 degenerates to 1·payload);
    * ``"reduce"`` — summable f32 tensors (DDP grads): bandwidth-optimal
      ring all-reduce, 2·(K-1)/K·payload;
    * ``"peer"``   — gossip: one peer payload, flat in K.
    """
    if collective == "gather":
        return payload_bytes * max(k - 1, 1)
    if collective == "reduce":
        if k <= 1:
            return payload_bytes
        return int(payload_bytes * 2 * (k - 1) / k)
    if collective == "peer":
        return payload_bytes
    raise ValueError(f"unknown collective {collective!r}; "
                     "expected gather | reduce | peer")


@functools.lru_cache(maxsize=1)
def _jit_rejoin_drift():
    """Jitted per-rejoiner drift probe: ``(state, live, w)`` -> (L2 norm of
    worker w's delta from the anchor, cosine of that delta against the
    live fleet's mean delta).  Fixed signature — ``live`` and ``w`` are
    traced, so rejoin events never retrace.  Called on the PRE-adoption
    state, so it measures exactly the divergence the rejoin erases."""
    from repro.core.drift import delta_cosine

    def impl(state, live, w):
        delta = jax.tree.map(
            lambda wp, g: wp.astype(jnp.float32) - g.astype(jnp.float32)[None],
            state.worker_params, state.global_params)
        dw = jax.tree.map(lambda d: d[w], delta)
        lf = live.astype(jnp.float32)
        n = jnp.maximum(jnp.sum(lf), 1.0)
        dmean = jax.tree.map(
            lambda d: jnp.tensordot(lf, d, axes=(0, 0)) / n, delta)
        norm = jnp.sqrt(outer_opt._tree_dot(dw, dw))
        return norm, delta_cosine(dw, dmean)

    return jax.jit(impl)


def _rejoin_drift_records(state, reset, live, step: int) -> Records:
    """``core.drift`` metrics for each rejoiner, recorded as
    ``("rejoin_drift", (step, worker, delta_norm, cos_to_live_mean))``."""
    recs: Records = []
    probe = _jit_rejoin_drift()
    live_arr = jnp.asarray(live)
    for w, r in enumerate(reset):
        if r:
            norm, cos = _fetch(probe(state, live_arr, jnp.int32(w)))
            recs.append(("rejoin_drift", (step, w, float(norm), float(cos))))
    return recs


class SyncRunner:
    """Per-run host-side state machine created by ``SyncStrategy.bind``.

    The chunked ``DistTrainer`` loop (``core.dist_trainer``) scans inner
    steps on device until the runner's next *event* — a step whose
    ``after_step`` touches device state (sync, snapshot, delayed apply).
    Between events ``after_step`` must be pure host bookkeeping (counters,
    loss windows) that ignores ``state``, because under chunking it is
    called with the post-chunk state for every step of the chunk.  When
    bound with ``donate=True`` the runner jits donate their
    state/residual arguments (params and momenta update in place), so any
    snapshot a runner keeps across steps must be a fresh buffer, never an
    alias of ``state`` leaves.
    """

    def after_step(self, state, step: int, loss: float):
        """Called after every inner step; returns (state, records)."""
        return state, []

    def next_event(self, step: int) -> Optional[int]:
        """First step >= ``step`` whose ``after_step`` may touch device
        state; ``None`` = no event before the run ends.  The base class is
        maximally conservative (every step is an event), which degrades
        the chunked loop to per-step execution."""
        return step

    def refresh(self, state):
        """Bring ``global_params`` up to date for an observer (eval hook);
        identity for strategies that maintain it on every sync."""
        return state

    def finalize(self, state, num_steps: int):
        """Called once after the last step; returns (state, records)."""
        return state, []

    # -- fault tolerance (quorum rounds + elastic rejoin) --------------------
    # Runners that understand per-worker fault events (crash / rejoin /
    # dropped payload) set ``supports_faults`` and accept a
    # ``core.faults.FleetTracker`` via ``bind_faults``; the trainer rejects
    # worker-level fault schedules for runners that do not.  Run-level
    # ``kill`` events (the crash/resume anchor) need no runner support.
    supports_faults = False

    def bind_faults(self, tracker) -> None:
        raise ValueError(
            f"{type(self).__name__} does not support per-worker fault "
            "injection (quorum sync / elastic rejoin); use one of the "
            "fault-aware strategies (diloco / ddp_compressed / streaming "
            "/ pipelined / gossip), or restrict the schedule to run-level "
            "kill/slow events")

    # -- crash-consistent checkpointing --------------------------------------
    def checkpoint_extras(self) -> Optional[Tuple[Any, Dict]]:
        """The runner-private state a resume needs: ``(arrays, meta)``
        where ``arrays`` is a pytree of device/host arrays (EF residuals,
        gossip anchors, ...) and ``meta`` is JSON-serializable host state
        (round counters, publish clocks).  Returns ``None`` when the
        runner is mid-round (e.g. a pipelined snapshot is in flight) and
        a checkpoint here would not be resumable — the trainer defers to
        the next clean chunk boundary.  The base runner is stateless, so
        any boundary is clean."""
        return {}, {}

    def load_extras(self, arrays, meta: Dict) -> None:
        """Restore what ``checkpoint_extras`` captured.  ``arrays`` is
        None when the checkpoint carried no array extras."""
        return None


class SyncStrategy:
    name = "base"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        """Create the per-run state machine.  ``donate`` controls whether
        the runner's outer-step jits donate their state/residual
        arguments (``DistTrainer.run`` threads its own ``donate`` flag
        here; the per-step reference loop passes False to keep the
        pre-chunking no-donation behaviour)."""
        raise NotImplementedError

    def payload_schedule(self, n_params: int, num_steps: int,
                         cfg: DiLoCoConfig) -> List[SyncEvent]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# DDP — synchronize every step
# ---------------------------------------------------------------------------

class _DDPRunner(SyncRunner):
    def after_step(self, state, step, loss):
        # K=1 + global batch: the worker IS the global model, synchronized
        # by construction — nothing to exchange, just record the cadence.
        return state, [("sync_steps", step)]

    def next_event(self, step):
        return None     # never touches device state between refreshes

    def refresh(self, state):
        gp = jax.tree.map(lambda w: w[0], state.worker_params)
        return state._replace(global_params=gp)

    def finalize(self, state, num_steps):
        return self.refresh(state), []


@dataclasses.dataclass(frozen=True)
class DDPSync(SyncStrategy):
    """Fully synchronous baseline: fp32 gradient all-reduce every step."""
    name = "ddp"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        if engine.cfg.num_workers != 1:
            raise ValueError(
                "DDPSync is the K=1 + global-batch baseline; "
                f"got num_workers={engine.cfg.num_workers}.  Use DiLoCoSync "
                "with H=1 for per-step delta averaging across workers.")
        return _DDPRunner()

    def payload_schedule(self, n_params, num_steps, cfg):
        # fp32 grads are summable: ring all-reduce, every step, blocking
        b = hop_bytes_per_worker(4 * n_params, cfg.num_workers, "reduce")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="grads",
                          apply_step=s) for s in range(num_steps)]


# ---------------------------------------------------------------------------
# Compressed DDP — per-step update exchange through a lossy codec
# ---------------------------------------------------------------------------

def compressed_ddp_config(cfg: DiLoCoConfig) -> DiLoCoConfig:
    """Fold ``cfg.grad_compress`` into a per-step delta-exchange config.

    H=1 with an identity outer update (lr=1, no momentum) makes the outer
    step exactly "average the workers' one-step parameter updates" — for
    SGD inner optimizers that is literally gradient averaging, and for
    AdamW/Muon it is DDP on the *effective update*, which is the quantity
    gradient-compression schemes actually care about.  The codec (and its
    error-feedback residual, held by the sync runner) then rides the same
    transport stack as every DiLoCo variant.
    """
    codec = cfg.grad_compress if cfg.grad_compress not in ("", "none") \
        else "float32"
    return dataclasses.replace(
        cfg, strategy="ddp_compressed", h_inner_steps=1, outer_lr=1.0,
        outer_momentum=0.0, nesterov=False, delta_dtype=codec)


@dataclasses.dataclass(frozen=True)
class CompressedDDPSync(SyncStrategy):
    """DDP with compressed per-step exchange: K workers average their
    one-step parameter updates through the configured codec every step.
    Build the config with ``compressed_ddp_config`` — the identity outer
    update (H=1, lr=1, mu=0) is what makes this DDP rather than DiLoCo;
    ``bind`` rejects configs that would silently change the semantics.
    Lossless codec => bitwise per-step delta-averaged DDP; int8/fp8 adds
    the quantizer + error feedback, the second anchor the benchmarks
    compare DiLoCo's bandwidth savings against."""
    name = "ddp_compressed"

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        cfg = engine.cfg
        if cfg.outer_lr != 1.0 or cfg.outer_momentum != 0.0 or cfg.nesterov:
            raise ValueError(
                "CompressedDDPSync needs the identity outer update "
                "(outer_lr=1, outer_momentum=0, nesterov=False) — build the "
                "config with sync.compressed_ddp_config(); got "
                f"lr={cfg.outer_lr} mu={cfg.outer_momentum} "
                f"nesterov={cfg.nesterov}")
        return _DiLoCoRunner(engine, params, FixedH(1), donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        codec = make_codec(cfg.delta_dtype if cfg.strategy == "ddp_compressed"
                           else (cfg.grad_compress
                                 if cfg.grad_compress not in ("", "none")
                                 else "float32"))
        b = hop_bytes_per_worker(codec.schedule_bytes(n_params),
                                 cfg.num_workers, "gather")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="grads",
                          apply_step=s, codec=codec.name)
                for s in range(num_steps)]


# ---------------------------------------------------------------------------
# DiLoCo — full delta exchange every H steps
# ---------------------------------------------------------------------------

class _DiLoCoRunner(SyncRunner):
    supports_faults = True

    def __init__(self, engine, params, hs: HSchedule, donate: bool = True):
        self.engine = engine
        self.hs = hs
        self.since = 0
        self.residual = engine.init_residual(params)
        self._donate = donate
        self._tracker = None
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def bind_faults(self, tracker):
        self._tracker = tracker
        d = (0, 1) if self._donate else ()
        self._quorum = jax.jit(self.engine.outer_step_quorum,
                               donate_argnums=d)
        self._adopt = jax.jit(self.engine.adopt_anchor, donate_argnums=d)

    def _sync(self, state, step):
        if self._tracker is None:
            # no fault schedule bound: the original jitted program,
            # untouched — the no-fault path stays bit-exact
            state, self.residual = self._outer(state, self.residual)
            return state, [("sync_steps", step)]
        info = self._tracker.round_masks(step)
        records = list(info.records)
        if any(info.reset):
            records += _rejoin_drift_records(state, info.reset, info.live,
                                             step)
        reset = jnp.asarray(info.reset)
        if info.skip:
            if any(info.reset):
                state, self.residual = self._adopt(state, self.residual,
                                                   reset)
            return state, records
        state, self.residual = self._quorum(
            state, self.residual, jnp.asarray(info.contrib),
            jnp.asarray(info.adopt), reset)
        records.append(("sync_steps", step))
        return state, records

    def after_step(self, state, step, loss):
        self.since += 1
        if self.hs.should_sync(step, self.since, loss):
            self.since = 0
            return self._sync(state, step)
        return state, []

    def finalize(self, state, num_steps):
        if self.since:  # trailing sync so global_params reflect all work
            return self._sync(state, num_steps - 1)
        return state, []

    def checkpoint_extras(self):
        if self.since:
            # mid-round: ``since`` (and AdaptiveH's loss window) are not
            # serialized — defer to the outer boundary, where both are
            # trivially zero/fresh
            return None
        return {"residual": self.residual}, {}

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.residual = arrays["residual"]

    def next_event(self, step):
        # syncs fire when since_sync reaches the schedule's current H, and
        # every supported HSchedule only changes H at a sync (AdaptiveH's
        # loss window is fed per step by after_step, but its slope check
        # runs at the boundary), so the next boundary is deterministic
        try:
            h = int(self.hs.current_h)
        except Exception:       # exotic schedule: degrade to per-step
            return step
        return step + max(h - self.since, 1) - 1


@dataclasses.dataclass(frozen=True)
class DiLoCoSync(SyncStrategy):
    """Paper §2.2: average parameter deltas + outer Nesterov SGD every H.

    ``h`` overrides the config's ``h_inner_steps``; ``h_schedule`` plugs in
    any ``HSchedule`` (e.g. ``AdaptiveH``) instead of fixed H.
    """
    name = "diloco"
    h: Optional[int] = None
    h_schedule: Optional[HSchedule] = None

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        hs = self.h_schedule or FixedH(self.h or engine.cfg.h_inner_steps)
        return _DiLoCoRunner(engine, params, hs, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = hop_bytes_per_worker(codec.schedule_bytes(n_params),
                                 cfg.num_workers, "gather")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s, codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Streaming DiLoCo — one fragment every H/F steps, staggered
# ---------------------------------------------------------------------------

class _StreamingRunner(SyncRunner):
    supports_faults = True

    def __init__(self, engine, params, donate: bool = True):
        from repro.core.streaming import fragment_masks
        self.engine = engine
        self.F = engine.num_fragments
        self.masks = fragment_masks(params, self.F)
        self.period = engine.fragment_schedule()
        self.residual = engine.init_residual(params)
        self._donate = donate
        self._tracker = None
        # donate state + residual (arg 1 is the reused fragment mask)
        self._frag = jax.jit(engine.outer_step_fragment_ef,
                             donate_argnums=(0, 2) if donate else ())

    def bind_faults(self, tracker):
        self._tracker = tracker
        self._fragq = jax.jit(self.engine.outer_step_fragment_quorum,
                              donate_argnums=(0, 2) if self._donate else ())
        self._adopt = jax.jit(self.engine.adopt_anchor,
                              donate_argnums=(0, 1) if self._donate else ())

    def after_step(self, state, step, loss):
        if (step + 1) % self.period == 0:
            f = ((step + 1) // self.period - 1) % self.F
            if self._tracker is None:
                state, self.residual = self._frag(state, self.masks[f],
                                                  self.residual)
                return state, [("frag_syncs", (step, f))]
            info = self._tracker.round_masks(step)
            records = list(info.records)
            if any(info.reset):
                records += _rejoin_drift_records(state, info.reset,
                                                 info.live, step)
            reset = jnp.asarray(info.reset)
            if info.skip:
                if any(info.reset):
                    state, self.residual = self._adopt(state, self.residual,
                                                       reset)
                return state, records
            state, self.residual = self._fragq(
                state, self.masks[f], self.residual,
                jnp.asarray(info.contrib), jnp.asarray(info.adopt), reset)
            records.append(("frag_syncs", (step, f)))
            return state, records
        return state, []

    def checkpoint_extras(self):
        # the fragment slot is a pure function of the step index and
        # un-synced divergence lives entirely in the state, so every
        # chunk boundary is clean
        return {"residual": self.residual}, {}

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.residual = arrays["residual"]

    def next_event(self, step):
        # fragment boundaries: every step s with (s + 1) % period == 0
        return (step // self.period + 1) * self.period - 1


@dataclasses.dataclass(frozen=True)
class StreamingSync(SyncStrategy):
    """Fragment-wise staggered sync (arXiv:2501.18512): every parameter
    still syncs each H, but instantaneous bandwidth demand drops F×."""
    name = "streaming"
    num_fragments: int = 4

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        return _StreamingRunner(engine, params, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = cfg.h_inner_steps
        period = max(h // self.num_fragments, 1)
        codec = make_codec(cfg.delta_dtype)
        b = hop_bytes_per_worker(
            codec.schedule_bytes(n_params // self.num_fragments),
            cfg.num_workers, "gather")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="fragment",
                          # a fragment may stream until its next slot
                          apply_step=s + period - 1,
                          fragment=((s + 1) // period - 1) % self.num_fragments,
                          codec=codec.name)
                for s in range(period - 1, num_steps, period)]


# ---------------------------------------------------------------------------
# Overlapped DiLoCo — delta captured at t, outer update applied at t+delay
# ---------------------------------------------------------------------------

class _OverlappedRunner(SyncRunner):
    """Captures per-worker delta snapshots (with straggler jitter) at each
    round boundary and applies the outer update ``delay`` steps later.
    Inner progress made during the communication window is carried forward:
    at apply time worker i becomes  new_global + (w_now_i − snap_i).
    With delay=0 and jitter=0 this is exactly ``DiLoCoSync``."""

    def __init__(self, engine, params, h: int, delay: int, jitter: int,
                 seed: int, donate: bool = True):
        if not 0 <= delay < h:
            raise ValueError(f"need 0 <= delay < h, got delay={delay} h={h}")
        if jitter < 0 or jitter + delay >= h:
            raise ValueError(
                f"need jitter + delay < h so every snapshot lands after the "
                f"previous apply, got jitter={jitter} delay={delay} h={h}")
        self.engine = engine
        self.h, self.delay, self.jitter = h, delay, jitter
        self.k = engine.cfg.num_workers
        self.seed = seed
        self.rng = _pyrandom.Random(seed)
        self.round_end = h - 1
        self.snap_steps = self._draw_snap_steps()
        self.buf = None                 # snapshot buffer being filled
        self.pending = None             # frozen snapshot awaiting apply
        self.pending_apply = -1
        self.residual = engine.init_residual(params)
        self._snap_row = jax.jit(
            lambda buf, wp, i: jax.tree.map(
                lambda b, w: b.at[i].set(w[i]), buf, wp))
        # donate state + residual; the snapshot is NOT donated — there is
        # no second (K, ...) output left to reuse its buffer for (the
        # worker-param output aliases the donated state's)
        self._apply = jax.jit(self._apply_impl,
                              donate_argnums=(0, 2) if donate else ())
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def _draw_snap_steps(self) -> Dict[int, int]:
        """Worker i's delta leaves jitter_i steps before the boundary — a
        straggler's contribution reflects fewer inner steps."""
        return {i: self.round_end
                - (self.rng.randint(0, self.jitter) if self.jitter else 0)
                for i in range(self.k)}

    def _apply_impl(self, state, snap, residual):
        cfg = self.engine.cfg
        delta = jax.tree.map(
            lambda s, g: s.astype(jnp.float32) - g.astype(jnp.float32)[None],
            snap, state.global_params)
        avg, new_res = outer_opt.exchange_and_average(
            delta, cfg, self.engine.replicate_fn, residual=residual)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, cfg)
        # carry forward the inner progress made while the exchange was in
        # flight: worker = synced base + (current − snapshot)
        new_wp = jax.tree.map(
            lambda w, s, ng: (ng.astype(jnp.float32)[None]
                              + (w.astype(jnp.float32) - s.astype(jnp.float32))
                              ).astype(w.dtype),
            state.worker_params, snap, new_global)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, outer=new_outer), new_res

    def after_step(self, state, step, loss):
        records: Records = []
        due = [i for i, s in self.snap_steps.items() if s == step]
        if due:
            if self.jitter == 0:
                # every worker snaps at the boundary: one whole-tree copy
                # (fresh buffers — the donated chunk/apply jits recycle
                # the state's, so the snapshot must never alias them)
                self.buf = jax.tree.map(jnp.copy, state.worker_params)
            else:
                if self.buf is None:
                    self.buf = state.worker_params
                for i in due:
                    # .at[].set yields fresh buffers, so the finished buf
                    # never aliases donated state leaves either
                    self.buf = self._snap_row(self.buf, state.worker_params,
                                              jnp.int32(i))
        if step == self.round_end:
            # every worker's snap step is <= round_end and was processed
            # above, so buf is always populated here
            self.pending = self.buf
            self.pending_apply = step + self.delay
            self.buf = None
            self.round_end += self.h
            self.snap_steps = self._draw_snap_steps()
        if self.pending is not None and step >= self.pending_apply:
            state, self.residual = self._apply(state, self.pending,
                                               self.residual)
            self.pending = None
            records.append(("sync_steps", step))
        return state, records

    def next_event(self, step):
        cands = [s for s in self.snap_steps.values() if s >= step]
        cands.append(self.round_end)
        if self.pending is not None:
            cands.append(max(self.pending_apply, step))
        return min(cands)

    def finalize(self, state, num_steps):
        records: Records = []
        if self.pending is not None:  # flush the in-flight round
            state, self.residual = self._apply(state, self.pending,
                                               self.residual)
            self.pending = None
            records.append(("sync_steps", num_steps - 1))
        if num_steps % self.h:        # trailing partial round: full sync
            state, self.residual = self._outer(state, self.residual)
            records.append(("sync_steps", num_steps - 1))
        return state, records

    def checkpoint_extras(self):
        if self.pending is not None or self.buf is not None:
            return None     # snapshot in flight: defer to a clean boundary
        return {"residual": self.residual}, {"round_end": self.round_end}

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.residual = arrays["residual"]
        # replay the jitter draws so the RNG stream continues bit-exactly
        self.rng = _pyrandom.Random(self.seed)
        self.round_end = self.h - 1
        self.snap_steps = self._draw_snap_steps()
        while self.round_end < int(meta["round_end"]):
            self.round_end += self.h
            self.snap_steps = self._draw_snap_steps()


@dataclasses.dataclass(frozen=True)
class OverlappedSync(SyncStrategy):
    """Streaming DiLoCo's overlapping communication for the *full* delta:
    capture at t, apply at t+delay, with per-worker straggler jitter.

    ``seed`` makes the jitter draws reproducible; ``make_strategy`` threads
    ``DiLoCoConfig.sync_seed`` here."""
    name = "overlapped"
    h: Optional[int] = None
    delay: int = 0
    jitter: int = 0
    seed: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        return _OverlappedRunner(engine, params, h, self.delay, self.jitter,
                                 self.seed, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = hop_bytes_per_worker(codec.schedule_bytes(n_params),
                                 cfg.num_workers, "gather")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s + self.delay, codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Pipelined (DiLoCoX) — ONE quantized fragment per round, delayed apply
# ---------------------------------------------------------------------------

class _PipelinedRunner(SyncRunner):
    """One fragment per outer round: at each H boundary the round's
    fragment (round mod F) is snapshotted, its encoded delta crosses the
    boundary while inner compute continues, and the outer update lands
    ``delay`` steps later.  Worker progress made in flight is carried
    forward on the fragment slots (like ``_OverlappedRunner``); the other
    slots keep diverging until their round comes up.  With F=1, delay=0
    this is exactly ``DiLoCoSync``."""

    supports_faults = True

    def __init__(self, engine, params, h: int, delay: int,
                 num_fragments: int, donate: bool = True):
        if not 0 <= delay < h:
            raise ValueError(f"need 0 <= delay < h, got delay={delay} h={h}")
        from repro.core.streaming import fragment_masks
        self.engine = engine
        self.h, self.delay, self.F = h, delay, num_fragments
        self.masks = fragment_masks(params, num_fragments)
        self.residual = engine.init_residual(params)
        self.round = 0
        self.pending = None   # (snapshot, fragment, RoundInfo|None) in flight
        self.pending_apply = -1
        self._donate = donate
        self._tracker = None
        self._apply = jax.jit(self._apply_impl, static_argnames=("frag",),
                              donate_argnums=(0, 2) if donate else ())
        self._outer = jax.jit(engine.outer_step_ef,
                              donate_argnums=(0, 1) if donate else ())

    def bind_faults(self, tracker):
        self._tracker = tracker
        d = (0, 2) if self._donate else ()
        self._applyq = jax.jit(self._apply_quorum_impl,
                               static_argnames=("frag",), donate_argnums=d)
        self._adopt = jax.jit(self.engine.adopt_anchor,
                              donate_argnums=(0, 1) if self._donate else ())
        self._quorum = jax.jit(self.engine.outer_step_quorum,
                               donate_argnums=(0, 1) if self._donate else ())

    def _apply_quorum_impl(self, state, snap, residual, contrib, adopt,
                           reset, *, frag: int):
        """``_apply_impl`` under quorum masks: ``contrib`` rows enter the
        fragment's masked average, ``adopt`` rows take the synced fragment
        slots with in-flight carry-forward, ``reset`` rows (rejoiners)
        land on the FULL new global with zeroed inner-opt/EF state, dead
        rows pass through frozen."""
        cfg = self.engine.cfg
        rows = outer_opt._mask_rows
        mask = self.masks[frag]
        delta = jax.tree.map(
            lambda s, g, m: (s.astype(jnp.float32)
                             - g.astype(jnp.float32)[None]) * m[None],
            snap, state.global_params, mask)
        res_in = residual if residual is None else jax.tree.map(
            lambda r, m: r * m[None], residual, mask)
        avg, new_res = outer_opt.exchange_and_average(
            delta, cfg, self.engine.replicate_fn, residual=res_in,
            kind="fragment", fragment=frag, live=contrib)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, cfg)
        new_global = jax.tree.map(
            lambda ng, g, m: jnp.where(m, ng, g),
            new_global, state.global_params, mask)
        new_wp = jax.tree.map(
            lambda w, s, ng, m: jnp.where(
                jnp.logical_and(rows(adopt, w), m[None]),
                (ng.astype(jnp.float32)[None]
                 + (w.astype(jnp.float32) - s.astype(jnp.float32))
                 ).astype(w.dtype),
                w),
            state.worker_params, snap, new_global, mask)
        new_wp = jax.tree.map(
            lambda w, ng: jnp.where(rows(reset, w),
                                    ng[None].astype(w.dtype), w),
            new_wp, new_global)
        new_opt = jax.tree.map(
            lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
            state.inner_opt)
        if residual is not None:
            new_res = jax.tree.map(
                lambda nr, r, m: jnp.where(
                    jnp.logical_and(rows(contrib, r), m[None]), nr, r),
                new_res, residual, mask)
            new_res = jax.tree.map(
                lambda r: jnp.where(rows(reset, r), jnp.zeros_like(r), r),
                new_res)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, inner_opt=new_opt,
                              outer=new_outer), new_res

    def _apply_impl(self, state, snap, residual, *, frag: int):
        cfg = self.engine.cfg
        mask = self.masks[frag]
        delta = jax.tree.map(
            lambda s, g, m: (s.astype(jnp.float32)
                             - g.astype(jnp.float32)[None]) * m[None],
            snap, state.global_params, mask)
        res_in = residual if residual is None else jax.tree.map(
            lambda r, m: r * m[None], residual, mask)
        avg, new_res = outer_opt.exchange_and_average(
            delta, cfg, self.engine.replicate_fn, residual=res_in,
            kind="fragment", fragment=frag)
        new_global, new_outer = outer_opt.outer_update(
            state.global_params, avg, state.outer, cfg)
        new_global = jax.tree.map(
            lambda ng, g, m: jnp.where(m, ng, g),
            new_global, state.global_params, mask)
        # fragment slots: synced base + progress made while in flight;
        # other slots untouched
        new_wp = jax.tree.map(
            lambda w, s, ng, m: jnp.where(
                m[None],
                (ng.astype(jnp.float32)[None]
                 + (w.astype(jnp.float32) - s.astype(jnp.float32))
                 ).astype(w.dtype),
                w),
            state.worker_params, snap, new_global, mask)
        if residual is not None:
            new_res = jax.tree.map(
                lambda nr, r, m: jnp.where(m[None], nr, r), new_res,
                residual, mask)
        return state._replace(global_params=new_global,
                              worker_params=new_wp, outer=new_outer), new_res

    def _apply_pending(self, state, step) -> Tuple[Any, Records]:
        snap, frag, info = self.pending
        self.pending = None
        if info is None:
            state, self.residual = self._apply(state, snap, self.residual,
                                               frag=frag)
            return state, [("frag_syncs", (step, frag))]
        records: Records = []
        if info.skip:
            if any(info.reset):
                state, self.residual = self._adopt(
                    state, self.residual, jnp.asarray(info.reset))
            return state, records
        # a worker that crashed while the snapshot was in flight must not
        # adopt the landing update: intersect with the tracker's live set
        adopt_now = tuple(a and l for a, l in
                          zip(info.adopt, self._tracker.live))
        state, self.residual = self._applyq(
            state, snap, self.residual, jnp.asarray(info.contrib),
            jnp.asarray(adopt_now), jnp.asarray(info.reset), frag=frag)
        records.append(("frag_syncs", (step, frag)))
        return state, records

    def after_step(self, state, step, loss):
        records: Records = []
        if (step + 1) % self.h == 0:
            info = None
            if self._tracker is not None:
                # masks captured WITH the snapshot: the deltas in flight
                # are the capture-time live set's
                info = self._tracker.round_masks(step)
                records += list(info.records)
                if any(info.reset):
                    records += _rejoin_drift_records(state, info.reset,
                                                     info.live, step)
            # copy, not alias: the chunked loop (and the donated apply)
            # consume the state's buffers while this snapshot is in flight
            self.pending = (jax.tree.map(jnp.copy, state.worker_params),
                            self.round % self.F, info)
            self.pending_apply = step + self.delay
            self.round += 1
        if self.pending is not None and step >= self.pending_apply:
            state, recs = self._apply_pending(state, step)
            records += recs
        return state, records

    def next_event(self, step):
        cands = [(step // self.h + 1) * self.h - 1]   # next round boundary
        if self.pending is not None:
            cands.append(max(self.pending_apply, step))
        return min(cands)

    def finalize(self, state, num_steps):
        records: Records = []
        if self.pending is not None:  # flush the in-flight fragment
            state, recs = self._apply_pending(state, num_steps - 1)
            records += recs
        if num_steps % self.h:        # trailing partial round: full sync
            if self._tracker is None:
                state, self.residual = self._outer(state, self.residual)
                records.append(("sync_steps", num_steps - 1))
            else:
                info = self._tracker.round_masks(num_steps - 1)
                records += list(info.records)
                if not info.skip:
                    state, self.residual = self._quorum(
                        state, self.residual, jnp.asarray(info.contrib),
                        jnp.asarray(info.adopt), jnp.asarray(info.reset))
                    records.append(("sync_steps", num_steps - 1))
        return state, records

    def checkpoint_extras(self):
        if self.pending is not None:
            return None     # fragment in flight: defer to a clean boundary
        return {"residual": self.residual}, {"round": self.round}

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.residual = arrays["residual"]
        self.round = int(meta["round"])


@dataclasses.dataclass(frozen=True)
class PipelinedSync(SyncStrategy):
    """DiLoCoX-style pipelined low-bandwidth sync (arXiv:2506.21263): one
    fragment per outer round, overlapped with compute via ``delay``.  Each
    parameter syncs every F·H steps — combine with the int8 codec for the
    compounded ~4F× boundary-byte reduction over f32 DiLoCo."""
    name = "pipelined"
    h: Optional[int] = None
    num_fragments: int = 4
    delay: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        return _PipelinedRunner(engine, params, h, self.delay,
                                self.num_fragments, donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = hop_bytes_per_worker(
            codec.schedule_bytes(n_params // self.num_fragments),
            cfg.num_workers, "gather")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="fragment",
                          apply_step=s + self.delay,
                          fragment=((s + 1) // h - 1) % self.num_fragments,
                          codec=codec.name)
                for s in range(h - 1, num_steps, h)]


# ---------------------------------------------------------------------------
# Gossip — no-all-reduce peer averaging (NoLoCo, arXiv:2506.10911)
# ---------------------------------------------------------------------------

GOSSIP_TOPOLOGIES = ("ring", "random", "full")


def _matching_from_order(order: List[int]) -> List[int]:
    """Pair consecutive entries of ``order`` into an involution: peer[i] is
    i's partner; an odd leftover is self-paired (a solo outer step)."""
    peer = list(range(len(order)))
    for a in range(0, len(order) - 1, 2):
        i, j = order[a], order[a + 1]
        peer[i], peer[j] = j, i
    return peer


def gossip_peers(k: int, round_idx: int, topology: str,
                 seed: int = 0) -> Optional[List[int]]:
    """The deterministic peer matching for one gossip round.

    Returns ``peer`` with ``peer[peer[i]] == i`` (an involution), or
    ``None`` for the full topology (average ALL workers — the DiLoCo
    mean).  ``ring`` alternates the pairing offset each round so
    information walks around the ring; ``random`` draws a fresh seeded
    matching per round (NoLoCo's schedule), keyed by ``(seed, round)`` so
    runs reproduce.
    """
    if topology == "full":
        return None
    if topology == "ring":
        off = round_idx % 2
        order = [(off + j) % k for j in range(k)]
    elif topology == "random":
        order = list(range(k))
        # int-keyed (tuple seeding is deprecated); still (seed, round)-unique
        _pyrandom.Random((seed << 32) ^ round_idx).shuffle(order)
    else:
        raise ValueError(f"unknown gossip topology {topology!r}; "
                         f"expected one of {GOSSIP_TOPOLOGIES}")
    return _matching_from_order(order)


@dataclasses.dataclass(frozen=True)
class GossipRound:
    """One gossip exchange for the event-driven simulator
    (``repro.launch.comm_sim.simulate_gossip``).

    ``emit_steps[w]`` is the (worker-local) step at which worker w ships
    its ``nbytes`` payload (-1 = w does not participate this round);
    ``deps[w]`` lists the ``(src_worker, src_emit_step)`` transfers w's
    apply consumes — a pair barrier for ring/random gossip, all K-1 peers
    for the full topology, empty when the contribution was dropped."""
    emit_steps: Tuple[int, ...]
    deps: Tuple[Tuple[Tuple[int, int], ...], ...]
    nbytes: int
    codec: str = "f32"


def _gossip_payload_bytes(codec, n_params: int) -> int:
    """One gossip publication on the wire: the codec'd delta PLUS the
    sender's f32 anchors and outer momentum (pair consensus averages the
    whole outer state — without it the receiver cannot mix, and the
    per-worker anchors random-walk apart; NoLoCo ships parameters for
    the same reason).  Still one flat peer payload, independent of fleet
    size — the all-reduce gather ships (K-1) of these."""
    return codec.schedule_bytes(n_params) + 2 * 4 * n_params


def _gossip_outer_rows(cfg, state, anchors, v, avg):
    """Per-row Nesterov outer update on stacked (K, ...) trees.  The math
    in ``outer_opt.outer_update`` is purely elementwise, so the stacked
    call IS the per-row update — no vmap needed, and the emitted code
    matches DiLoCoSync's unstacked call (pinned by the K=2 equivalence
    test)."""
    new_anchors, ostate = outer_opt.outer_update(
        anchors, avg, outer_opt.OuterState(v=v, t=state.outer.t), cfg)
    return new_anchors, ostate.v


def _gossip_new_state(state, new_anchors):
    """Worker params land on their updated anchors; ``global_params``
    tracks the anchor mean (the fleet consensus estimate) so eval /
    checkpoint consumers keep working; ``state.outer`` only counts."""
    new_wp = jax.tree.map(lambda a, w: a.astype(w.dtype),
                          new_anchors, state.worker_params)
    new_global = jax.tree.map(
        lambda a, g: jnp.mean(a.astype(jnp.float32), axis=0).astype(g.dtype),
        new_anchors, state.global_params)
    return state._replace(
        global_params=new_global, worker_params=new_wp,
        outer=outer_opt.OuterState(state.outer.v, state.outer.t + 1))


def _gossip_pair_impl(cfg, replicate_fn, state, anchors, v, residual,
                      peer_idx):
    """One synchronized gossip round: encode per-worker deltas, ship ONE
    peer row each, pair-average, per-row outer update.

    Module-level on purpose: ``GossipSync`` and the fully-synchronous
    ``AsyncGossipSync`` specialization jit THIS SAME function, so bitwise
    equality between them is structural (one traced module), not a
    compiler accident — XLA:CPU contracts mul+add chains to FMAs per
    module at the LLVM level, below HLO, so even ``optimization_barrier``
    cannot pin cross-module rounding."""
    transport = outer_opt.make_transport(cfg, replicate_fn)
    delta = jax.tree.map(
        lambda w, a: w.astype(jnp.float32) - a.astype(jnp.float32),
        state.worker_params, anchors)
    dq, peer_dq, new_res = transport.exchange_peers(delta, peer_idx,
                                                    residual)
    # pair CONSENSUS: the pair averages its whole OUTER STATE — anchors
    # and outer momentum — not just its deltas (NoLoCo ships parameters
    # for the same reason).  Delta-only averaging leaves the per-worker
    # anchors on an uncontracted random walk and the fleet never agrees;
    # unmixed momentum keeps amplifying per-worker disagreement.  The
    # matching is an involution, so both pair members compute the same
    # mix and land on IDENTICAL outer state; x*0.5 is exact, so with
    # equal rows (K=2) the mix is bitwise a no-op and the 2-row mean is
    # bitwise the DiLoCo mean (a+b)/2.
    def pair_mean(t):
        peer_rows = jax.tree.map(lambda x: x[peer_idx], t)
        return jax.tree.map(lambda a, b: a * 0.5 + b * 0.5, t, peer_rows)

    base, v_mix = pair_mean(anchors), pair_mean(v)
    avg = jax.tree.map(lambda a, b: a * 0.5 + b * 0.5, dq, peer_dq)
    new_anchors, new_v = _gossip_outer_rows(cfg, state, base, v_mix, avg)
    return _gossip_new_state(state, new_anchors), new_anchors, new_v, new_res


def _gossip_async_impl(cfg, replicate_fn, state, anchors, v, residual, pub,
                       pub_anch, pub_v, due, peer, base_w, gate):
    """One async-gossip apply event with a dynamic due-set.

    ``due``/``peer``/``base_w``/``gate`` are (K,) arrays — the jit
    signature is fixed, so a changing due-set or matching never
    retraces.  All rows are encoded in one fixed-shape pass; non-due rows
    (params, momentum, EF residual, published delta) are masked back to
    their previous values, so a worker that shipped nothing advances
    nothing."""
    from repro.core.drift import delta_cosine
    transport = outer_opt.make_transport(cfg, replicate_fn)

    def rows(m, a):      # (K,) mask/weight -> broadcast over a row tree
        return m.reshape((-1,) + (1,) * (a.ndim - 1))

    delta = jax.tree.map(
        lambda w, a: w.astype(jnp.float32) - a.astype(jnp.float32),
        state.worker_params, anchors)
    dq, new_res = transport.exchange(delta, residual)

    def publish(new, old):
        return jax.tree.map(
            lambda n, o: jnp.where(rows(due, n), n, o), new, old)

    # a publication is (delta, anchors, momentum)-at-publish: the
    # consumer mixes the whole outer state, so the pair-consensus
    # contraction survives the missing barrier
    pub_new = publish(dq, pub)
    pub_anch_new = publish(anchors, pub_anch)
    pub_v_new = publish(v, pub_v)
    peer_dq = jax.tree.map(lambda p: p[peer], pub_new)
    # observed drift: a stale peer delta pointing away from the local one
    # is down-weighted toward zero (gate is set only for 0 < s <= bound)
    cos = jax.vmap(delta_cosine)(dq, peer_dq)                        # (K,)
    w_eff = jnp.where(gate, base_w * jnp.maximum(cos, 0.0), base_w)

    def mix(own, published):
        peer_rows = jax.tree.map(lambda p: p[peer], published)
        return jax.tree.map(
            lambda a, b: a * rows(1.0 - w_eff, a) + b * rows(w_eff, b),
            own, peer_rows)

    avg = jax.tree.map(
        lambda a, b: a * rows(1.0 - w_eff, a) + b * rows(w_eff, b),
        dq, peer_dq)
    base = mix(anchors, pub_anch_new)
    v_mix = mix(v, pub_v_new)
    cand_anchors, cand_v = _gossip_outer_rows(cfg, state, base, v_mix, avg)

    def merge(n, o):
        return jnp.where(rows(due, n), n, o)

    new_anchors = jax.tree.map(merge, cand_anchors, anchors)
    new_v = jax.tree.map(merge, cand_v, v)
    new_wp = jax.tree.map(
        lambda a, wp: jnp.where(rows(due, wp), a.astype(wp.dtype), wp),
        new_anchors, state.worker_params)
    if residual is not None:
        new_res = jax.tree.map(merge, new_res, residual)
    new_global = jax.tree.map(
        lambda a, g: jnp.mean(a.astype(jnp.float32), axis=0).astype(g.dtype),
        new_anchors, state.global_params)
    new_state = state._replace(
        global_params=new_global, worker_params=new_wp,
        outer=outer_opt.OuterState(state.outer.v, state.outer.t + 1))
    return (new_state, new_anchors, new_v, new_res, pub_new, pub_anch_new,
            pub_v_new)


def _gossip_pair_live_impl(cfg, replicate_fn, state, anchors, v, residual,
                           peer_idx, active, adopt, reset):
    """``_gossip_pair_impl`` under quorum masks (all (K,) traced arrays —
    fixed signature, a changing live set never retraces):

    * ``active`` — contributors, pair-matched among themselves (a solo
      leftover self-pairs: its pair mean is the identity, a solo outer
      step);
    * ``adopt``  — live veterans, whose post-round anchor mean is the
      consensus estimate a rejoiner adopts;
    * ``reset``  — rejoiners: anchors/params := consensus, outer momentum,
      inner-opt and EF state := 0;
    * rows in none of the masks (dead workers) pass through frozen.
    """
    rows = outer_opt._mask_rows
    transport = outer_opt.make_transport(cfg, replicate_fn)
    delta = jax.tree.map(
        lambda w, a: w.astype(jnp.float32) - a.astype(jnp.float32),
        state.worker_params, anchors)
    dq, peer_dq, new_res = transport.exchange_peers(delta, peer_idx,
                                                    residual)

    def pair_mean(t):
        peer_rows = jax.tree.map(lambda x: x[peer_idx], t)
        return jax.tree.map(lambda a, b: a * 0.5 + b * 0.5, t, peer_rows)

    base, v_mix = pair_mean(anchors), pair_mean(v)
    avg = jax.tree.map(lambda a, b: a * 0.5 + b * 0.5, dq, peer_dq)
    cand_anchors, cand_v = _gossip_outer_rows(cfg, state, base, v_mix, avg)

    def merge(n, o):
        return jnp.where(rows(active, n), n, o)

    new_anchors = jax.tree.map(merge, cand_anchors, anchors)
    new_v = jax.tree.map(merge, cand_v, v)
    if new_res is not None:
        new_res = jax.tree.map(merge, new_res, residual)
    # rejoiners adopt the veterans' consensus with a clean slate
    af = adopt.astype(jnp.float32)
    n_adopt = jnp.maximum(jnp.sum(af), 1.0)
    consensus = jax.tree.map(
        lambda a: jnp.tensordot(af, a.astype(jnp.float32),
                                axes=(0, 0)) / n_adopt, new_anchors)
    new_anchors = jax.tree.map(
        lambda a, c: jnp.where(rows(reset, a), c[None].astype(a.dtype), a),
        new_anchors, consensus)
    new_v = jax.tree.map(
        lambda x: jnp.where(rows(reset, x), jnp.zeros_like(x), x), new_v)
    if new_res is not None:
        new_res = jax.tree.map(
            lambda r: jnp.where(rows(reset, r), jnp.zeros_like(r), r),
            new_res)
    take = jnp.logical_or(active, reset)
    new_wp = jax.tree.map(
        lambda a, w: jnp.where(rows(take, w), a.astype(w.dtype), w),
        new_anchors, state.worker_params)
    new_opt = jax.tree.map(
        lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
        state.inner_opt)
    # global tracks the LIVE fleet's anchor mean; dead anchors are stale
    lf = jnp.logical_or(adopt, reset).astype(jnp.float32)
    n_live = jnp.maximum(jnp.sum(lf), 1.0)
    new_global = jax.tree.map(
        lambda a, g: (jnp.tensordot(lf, a.astype(jnp.float32),
                                    axes=(0, 0)) / n_live).astype(g.dtype),
        new_anchors, state.global_params)
    new_state = state._replace(
        global_params=new_global, worker_params=new_wp, inner_opt=new_opt,
        outer=outer_opt.OuterState(state.outer.v, state.outer.t + 1))
    return new_state, new_anchors, new_v, new_res


def _gossip_adopt_impl(cfg, state, anchors, v, residual, reset, adopt):
    """Rejoin on a skipped gossip round: ``reset`` rows adopt the ``adopt``
    rows' CURRENT anchor consensus (no exchange, no outer update); the
    veterans are untouched."""
    del cfg     # uniform partial-binding signature with the pair impls
    rows = outer_opt._mask_rows
    af = adopt.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(af), 1.0)
    consensus = jax.tree.map(
        lambda a: jnp.tensordot(af, a.astype(jnp.float32), axes=(0, 0)) / n,
        anchors)
    new_anchors = jax.tree.map(
        lambda a, c: jnp.where(rows(reset, a), c[None].astype(a.dtype), a),
        anchors, consensus)
    new_v = jax.tree.map(
        lambda x: jnp.where(rows(reset, x), jnp.zeros_like(x), x), v)
    if residual is not None:
        residual = jax.tree.map(
            lambda r: jnp.where(rows(reset, r), jnp.zeros_like(r), r),
            residual)
    new_wp = jax.tree.map(
        lambda a, w: jnp.where(rows(reset, w), a.astype(w.dtype), w),
        new_anchors, state.worker_params)
    new_opt = jax.tree.map(
        lambda o: jnp.where(rows(reset, o), jnp.zeros_like(o), o),
        state.inner_opt)
    lf = jnp.logical_or(adopt, reset).astype(jnp.float32)
    n_live = jnp.maximum(jnp.sum(lf), 1.0)
    new_global = jax.tree.map(
        lambda a, g: (jnp.tensordot(lf, a.astype(jnp.float32),
                                    axes=(0, 0)) / n_live).astype(g.dtype),
        new_anchors, state.global_params)
    return state._replace(global_params=new_global, worker_params=new_wp,
                          inner_opt=new_opt), new_anchors, new_v, residual


def _jit_gossip_pair(engine, donate: bool):
    fn = functools.partial(_gossip_pair_impl, engine.cfg,
                           engine.replicate_fn)
    return jax.jit(fn, donate_argnums=(0, 1, 2, 3) if donate else ())


def _jit_gossip_pair_live(engine, donate: bool):
    fn = functools.partial(_gossip_pair_live_impl, engine.cfg,
                           engine.replicate_fn)
    return jax.jit(fn, donate_argnums=(0, 1, 2, 3) if donate else ())


def _jit_gossip_adopt(engine, donate: bool):
    fn = functools.partial(_gossip_adopt_impl, engine.cfg)
    return jax.jit(fn, donate_argnums=(0, 1, 2, 3) if donate else ())


class _GossipRunner(SyncRunner):
    """Synchronized gossip rounds: every H steps each worker encodes its
    delta against its OWN anchor, exchanges (delta, anchors, momentum)
    with one peer from the topology schedule, and applies a per-worker
    Nesterov outer update from the pair-averaged outer state on the
    pair-averaged delta — so each pairing contracts the pair to an
    IDENTICAL outer state and the fleet gossips toward consensus.  Anchors and outer momentum live
    per worker (the runner holds them, like the EF residual —
    ``DiLoCoState`` and checkpoints are untouched); ``global_params``
    tracks the anchor mean at every sync.  With K=2 any pairing is the
    pair mean over shared anchors, so this is bit-exact ``DiLoCoSync``;
    the full topology binds ``_DiLoCoRunner`` directly (see
    ``GossipSync.bind``)."""

    supports_faults = True

    def __init__(self, engine, params, h: int, topology: str, seed: int,
                 donate: bool = True):
        from repro.core.diloco import _broadcast
        if topology == "full":
            raise ValueError("full topology is the DiLoCo mean — "
                             "GossipSync.bind delegates it to _DiLoCoRunner")
        gossip_peers(2, 0, topology, seed)   # validate the topology name
        self.engine = engine
        self.h = h
        self.topology = topology
        self.seed = seed
        self.k = engine.cfg.num_workers
        self.since = 0
        self.round = 0
        self.anchors = _broadcast(params, self.k)
        self.outer_v = jax.tree.map(
            lambda p: jnp.zeros((self.k,) + p.shape, jnp.float32), params)
        self.residual = engine.init_residual(params)
        self._donate = donate
        self._tracker = None
        self._sync = _jit_gossip_pair(engine, donate)

    def bind_faults(self, tracker):
        self._tracker = tracker
        self._syncq = _jit_gossip_pair_live(self.engine, self._donate)
        self._adoptg = _jit_gossip_adopt(self.engine, self._donate)

    def _do_sync(self, state, step):
        if self._tracker is None:
            peers = gossip_peers(self.k, self.round, self.topology,
                                 self.seed)
            records = [("gossip_syncs", (step, w, peers[w], 0))
                       for w in range(self.k)]
            records.append(("sync_steps", step))
            state, self.anchors, self.outer_v, self.residual = self._sync(
                state, self.anchors, self.outer_v, self.residual,
                jnp.asarray(peers, jnp.int32))
            self.round += 1
            return state, records
        info = self._tracker.round_masks(step)
        records = list(info.records)
        if any(info.reset):
            records += _rejoin_drift_records(state, info.reset, info.live,
                                             step)
        reset = jnp.asarray(info.reset)
        adopt = jnp.asarray(info.adopt)
        if info.skip:
            if any(info.reset):
                (state, self.anchors, self.outer_v,
                 self.residual) = self._adoptg(
                    state, self.anchors, self.outer_v, self.residual,
                    reset, adopt)
            self.round += 1
            return state, records
        # deterministic matching over the surviving contributors only:
        # the sub-fleet's schedule is mapped back through the sorted
        # contributor indices, so any two boxes replaying the same
        # schedule pair the same workers
        contributors = [w for w in range(self.k) if info.contrib[w]]
        sub = gossip_peers(len(contributors), self.round, self.topology,
                           self.seed)
        peers = list(range(self.k))
        for i, w in enumerate(contributors):
            peers[w] = contributors[sub[i]]
        for w in contributors:
            records.append(("gossip_syncs", (step, w, peers[w], 0)))
        records.append(("sync_steps", step))
        state, self.anchors, self.outer_v, self.residual = self._syncq(
            state, self.anchors, self.outer_v, self.residual,
            jnp.asarray(peers, jnp.int32), jnp.asarray(info.contrib),
            adopt, reset)
        self.round += 1
        return state, records

    def after_step(self, state, step, loss):
        self.since += 1
        if self.since >= self.h:
            self.since = 0
            return self._do_sync(state, step)
        return state, []

    def next_event(self, step):
        return step + max(self.h - self.since, 1) - 1

    def finalize(self, state, num_steps):
        if self.since:  # trailing partial round
            return self._do_sync(state, num_steps - 1)
        return state, []

    def checkpoint_extras(self):
        if self.since:
            return None     # mid-round: defer to the gossip boundary
        return ({"anchors": self.anchors, "outer_v": self.outer_v,
                 "residual": self.residual}, {"round": self.round})

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.anchors = arrays["anchors"]
            self.outer_v = arrays["outer_v"]
            self.residual = arrays["residual"]
        self.round = int(meta["round"])


@dataclasses.dataclass(frozen=True)
class GossipSync(SyncStrategy):
    """NoLoCo-style gossip outer sync: each round every worker averages
    anchors AND deltas with ONE peer from a deterministic ``topology``
    schedule (ring / random matching / full, keyed by ``seed``), the
    delta shipped through the codec transport — so per-worker boundary
    traffic is one flat peer payload regardless of fleet size, and
    fp8/int8 wire compression of the delta composes for free."""
    name = "gossip"
    h: Optional[int] = None
    topology: str = "ring"
    seed: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        if self.topology == "full" or engine.cfg.num_workers == 2:
            # the full matching — and K=2, where the one pair IS the
            # fleet — averages ALL workers at once: definitionally the
            # DiLoCo mean, so it binds the DiLoCo runner itself and the
            # equivalence is structural (bitwise by shared compilation,
            # not a per-module FMA-contraction accident)
            return _DiLoCoRunner(engine, params, FixedH(h), donate)
        return _GossipRunner(engine, params, h, self.topology, self.seed,
                             donate)

    def payload_schedule(self, n_params, num_steps, cfg):
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        if self.topology == "full":
            # the DiLoCo mean: anchors are common knowledge, only the
            # codec'd deltas travel (all-gather)
            b = hop_bytes_per_worker(codec.schedule_bytes(n_params),
                                     cfg.num_workers, "gather")
        else:
            b = hop_bytes_per_worker(_gossip_payload_bytes(codec, n_params),
                                     cfg.num_workers, "peer")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s, codec=codec.name)
                for s in range(h - 1, num_steps, h)]

    def gossip_rounds(self, n_params, num_steps, cfg) -> List[GossipRound]:
        """Per-pair event model for ``comm_sim.simulate_gossip``."""
        h = self.h or cfg.h_inner_steps
        k = cfg.num_workers
        codec = make_codec(cfg.delta_dtype)
        b = (codec.schedule_bytes(n_params) if self.topology == "full"
             else _gossip_payload_bytes(codec, n_params))
        rounds = []
        for r, s in enumerate(range(h - 1, num_steps, h)):
            peers = gossip_peers(k, r, self.topology, self.seed)
            if peers is None:
                deps = tuple(tuple((j, s) for j in range(k) if j != w)
                             for w in range(k))
            else:
                deps = tuple(((peers[w], s),) if peers[w] != w else ()
                             for w in range(k))
            rounds.append(GossipRound(emit_steps=(s,) * k, deps=deps,
                                      nbytes=b, codec=codec.name))
        return rounds


class _AsyncGossipRunner(SyncRunner):
    """Gossip on per-worker step clocks: worker i syncs every
    ``periods[i] = H + jitter_i`` steps against the latest
    (delta, anchors, momentum) its peer PUBLISHED (no barrier).  The
    apply rule weights the peer contribution — outer-state mix and delta
    average alike — by its observed staleness
    s = own_step - peer_publish_step:

    * s == 0            — peer is co-due: plain 0.5/0.5 pair average;
    * 0 < s <= bound    — base weight 0.5·(1 - s/(bound+1)), further
                          scaled by the observed drift
                          ``max(cos(own_delta, peer_delta), 0)``
                          (``repro.core.drift.delta_cosine``);
    * s > bound / none  — dropped: solo outer step on the own delta.

    One fixed-signature jit applies every event (due/peer/weight/gate are
    dynamic (K,) arrays — a changing due-set never retraces); non-due
    rows pass through untouched, including their EF residual.  With
    jitter=0 and bound=0 every worker is co-due every H with staleness 0,
    and the apply specializes to the SAME jitted pair graph
    ``_GossipRunner`` uses — the reduction to the synchronous barrier is
    bit-exact by construction."""

    def __init__(self, engine, params, h: int, topology: str,
                 staleness_bound: int, jitter: int, seed: int,
                 donate: bool = True):
        if topology == "full":
            raise ValueError(
                "async gossip is peer-based; topology='full' is the "
                "synchronous DiLoCo mean — use GossipSync(topology='full') "
                "or DiLoCoSync")
        from repro.core.diloco import _broadcast
        gossip_peers(2, 0, topology, seed)   # validate the topology name
        if jitter < 0 or staleness_bound < 0:
            raise ValueError(
                f"jitter and staleness_bound must be >= 0, got "
                f"jitter={jitter} staleness_bound={staleness_bound}")
        self.engine = engine
        self.k = k = engine.cfg.num_workers
        self.h, self.topology = h, topology
        self.bound = staleness_bound
        self.seed = seed
        rng = _pyrandom.Random(seed)
        self.periods = tuple(
            h + (rng.randint(0, jitter) if jitter else 0) for _ in range(k))
        self.fully_sync = (jitter == 0 and staleness_bound == 0)
        self.anchors = _broadcast(params, k)
        self.outer_v = jax.tree.map(
            lambda p: jnp.zeros((k,) + p.shape, jnp.float32), params)
        self.residual = engine.init_residual(params)
        self.pub_step = [-(10 ** 9)] * k      # host-side publish clocks
        self.rounds = [0] * k
        if self.fully_sync:
            self.pub = self.pub_anch = self.pub_v = None
            self._apply_pair = _jit_gossip_pair(engine, donate)
        else:
            # published (decoded delta, anchors, momentum), device-held
            self.pub = jax.tree.map(
                lambda p: jnp.zeros((k,) + p.shape, jnp.float32), params)
            self.pub_anch = jax.tree.map(jnp.zeros_like, self.anchors)
            self.pub_v = jax.tree.map(jnp.zeros_like, self.outer_v)
            fn = functools.partial(_gossip_async_impl, engine.cfg,
                                   engine.replicate_fn)
            self._apply = jax.jit(
                fn, donate_argnums=(0, 1, 2, 3, 4, 5, 6) if donate else ())

    def _do_apply(self, state, step, due):
        k = self.k
        peer = list(range(k))
        base_w = [0.0] * k
        gate = [False] * k
        records = []
        for w in due:                     # publish BEFORE any read, so a
            self.pub_step[w] = step       # co-due peer is staleness 0
        for w in due:
            p = gossip_peers(k, self.rounds[w], self.topology, self.seed)[w]
            peer[w] = p
            s = step - self.pub_step[p] if self.pub_step[p] >= 0 else -1
            if p == w or s < 0 or s > self.bound:
                base_w[w] = 0.0           # drop: solo outer step
            elif s == 0:
                base_w[w] = 0.5
            else:
                base_w[w] = 0.5 * (1.0 - s / (self.bound + 1.0))
                gate[w] = True            # stale: drift-reweighted
            records.append(("gossip_syncs", (step, w, p, s)))
            self.rounds[w] += 1
        if len(due) == k:
            records.append(("sync_steps", step))
        if self.fully_sync:
            # equal clocks + bound 0: due is always the whole fleet and
            # every peer co-due — run the synchronous pair graph
            state, self.anchors, self.outer_v, self.residual = (
                self._apply_pair(state, self.anchors, self.outer_v,
                                 self.residual,
                                 jnp.asarray(peer, jnp.int32)))
            return state, records
        due_set = set(due)
        (state, self.anchors, self.outer_v, self.residual,
         self.pub, self.pub_anch, self.pub_v) = self._apply(
            state, self.anchors, self.outer_v, self.residual, self.pub,
            self.pub_anch, self.pub_v,
            jnp.asarray([w in due_set for w in range(k)], bool),
            jnp.asarray(peer, jnp.int32),
            jnp.asarray(base_w, jnp.float32),
            jnp.asarray(gate, bool))
        return state, records

    def after_step(self, state, step, loss):
        due = [w for w in range(self.k)
               if (step + 1) % self.periods[w] == 0]
        if not due:
            return state, []
        return self._do_apply(state, step, due)

    def next_event(self, step):
        return min((step // p + 1) * p - 1 for p in self.periods)

    def finalize(self, state, num_steps):
        due = [w for w in range(self.k) if num_steps % self.periods[w] != 0]
        if not due:
            return state, []
        return self._do_apply(state, num_steps - 1, due)

    def checkpoint_extras(self):
        # the publish board and clocks capture everything in flight, so
        # every chunk boundary is clean
        arrays = {"anchors": self.anchors, "outer_v": self.outer_v,
                  "residual": self.residual}
        if not self.fully_sync:
            arrays.update(pub=self.pub, pub_anch=self.pub_anch,
                          pub_v=self.pub_v)
        return arrays, {"pub_step": list(self.pub_step),
                        "rounds": list(self.rounds)}

    def load_extras(self, arrays, meta):
        if arrays is not None:
            self.anchors = arrays["anchors"]
            self.outer_v = arrays["outer_v"]
            self.residual = arrays["residual"]
            if not self.fully_sync:
                self.pub = arrays["pub"]
                self.pub_anch = arrays["pub_anch"]
                self.pub_v = arrays["pub_v"]
        self.pub_step = [int(x) for x in meta["pub_step"]]
        self.rounds = [int(x) for x in meta["rounds"]]


@dataclasses.dataclass(frozen=True)
class AsyncGossipSync(SyncStrategy):
    """Gossip on per-worker step clocks with a staleness-aware apply rule:
    worker i syncs every ``H + jitter_i`` steps (jitter drawn from
    ``seed``), consumes its peer's latest PUBLISHED delta without a
    barrier, and drops or drift-reweights contributions staler than
    ``staleness_bound`` inner steps.  ``jitter=0, staleness_bound=0`` is
    bit-exact ``GossipSync`` (the synchronous barrier)."""
    name = "async_gossip"
    h: Optional[int] = None
    topology: str = "ring"
    staleness_bound: int = 0
    jitter: int = 0
    seed: int = 0

    def bind(self, engine, params, donate: bool = True) -> SyncRunner:
        h = self.h or engine.cfg.h_inner_steps
        if (self.jitter == 0 and self.staleness_bound == 0
                and engine.cfg.num_workers == 2
                and self.topology != "full"):
            # equal clocks + bound 0 + one pair: the synchronous fleet
            # mean — same structural delegation as GossipSync at K=2
            # (full still falls through to the runner's rejection)
            gossip_peers(2, 0, self.topology, self.seed)  # validate name
            return _DiLoCoRunner(engine, params, FixedH(h), donate)
        return _AsyncGossipRunner(engine, params, h, self.topology,
                                  self.staleness_bound, self.jitter,
                                  self.seed, donate)

    def _periods(self, h: int, k: int) -> Tuple[int, ...]:
        rng = _pyrandom.Random(self.seed)
        return tuple(
            h + (rng.randint(0, self.jitter) if self.jitter else 0)
            for _ in range(k))

    def payload_schedule(self, n_params, num_steps, cfg):
        # the mean worker's footprint: one peer payload every ~H steps,
        # with the staleness window as overlap budget; the per-worker
        # event model (per-pair barriers, per-worker clocks) is
        # gossip_rounds + comm_sim.simulate_gossip
        h = self.h or cfg.h_inner_steps
        codec = make_codec(cfg.delta_dtype)
        b = hop_bytes_per_worker(_gossip_payload_bytes(codec, n_params),
                                 cfg.num_workers, "peer")
        return [SyncEvent(step=s, bytes_per_worker=b, kind="delta",
                          apply_step=s + self.staleness_bound,
                          codec=codec.name)
                for s in range(h - 1, num_steps, h)]

    def gossip_rounds(self, n_params, num_steps, cfg) -> List[GossipRound]:
        """Replay the runner's publish/consume schedule as simulator
        events: one ``GossipRound`` per step with due workers, pair deps
        only for consumed (non-dropped) contributions."""
        h = self.h or cfg.h_inner_steps
        k = cfg.num_workers
        codec = make_codec(cfg.delta_dtype)
        b = _gossip_payload_bytes(codec, n_params)
        periods = self._periods(h, k)
        pub = [-(10 ** 9)] * k
        rounds_count = [0] * k
        out = []
        for step in range(num_steps):
            due = [w for w in range(k) if (step + 1) % periods[w] == 0]
            if not due:
                continue
            for w in due:
                pub[w] = step
            emit = [-1] * k
            deps: List[Tuple] = [()] * k
            for w in due:
                emit[w] = step
                p = gossip_peers(k, rounds_count[w], self.topology,
                                 self.seed)[w]
                s = step - pub[p]
                if p != w and s <= self.staleness_bound:
                    deps[w] = ((p, pub[p]),)
                rounds_count[w] += 1
            out.append(GossipRound(emit_steps=tuple(emit), deps=tuple(deps),
                                   nbytes=b, codec=codec.name))
        return out


# ---------------------------------------------------------------------------
# Config-driven construction — declarative method -> factory registry
# ---------------------------------------------------------------------------

# name -> factory(cfg, h_schedule) -> SyncStrategy.  New strategies register
# in one line; launch.train derives its --method choices from this table.
_STRATEGY_REGISTRY: Dict[str, Any] = {}


def register_strategy(name: str):
    """Decorator: register a ``factory(cfg, h_schedule) -> SyncStrategy``
    under ``name`` (the ``DiLoCoConfig.strategy`` spelling)."""
    def deco(factory):
        _STRATEGY_REGISTRY[name] = factory
        return factory
    return deco


def strategy_names() -> Tuple[str, ...]:
    """Registered strategy names, in registration order."""
    return tuple(_STRATEGY_REGISTRY)


@register_strategy("ddp")
def _ddp_factory(cfg, h_schedule):
    return DDPSync()


@register_strategy("ddp_compressed")
def _ddp_compressed_factory(cfg, h_schedule):
    return CompressedDDPSync()


@register_strategy("diloco")
def _diloco_factory(cfg, h_schedule):
    return DiLoCoSync(h_schedule=h_schedule)


@register_strategy("streaming")
def _streaming_factory(cfg, h_schedule):
    return StreamingSync(num_fragments=cfg.num_fragments)


@register_strategy("overlapped")
def _overlapped_factory(cfg, h_schedule):
    return OverlappedSync(delay=cfg.sync_delay, jitter=cfg.h_jitter,
                          seed=cfg.sync_seed)


@register_strategy("pipelined")
def _pipelined_factory(cfg, h_schedule):
    return PipelinedSync(num_fragments=cfg.num_fragments,
                         delay=cfg.sync_delay)


@register_strategy("gossip")
def _gossip_factory(cfg, h_schedule):
    return GossipSync(topology=cfg.topology, seed=cfg.sync_seed)


@register_strategy("async_gossip")
def _async_gossip_factory(cfg, h_schedule):
    return AsyncGossipSync(topology=cfg.topology,
                           staleness_bound=cfg.staleness_bound,
                           jitter=cfg.h_jitter, seed=cfg.sync_seed)


STRATEGIES = strategy_names()


def make_strategy(cfg: DiLoCoConfig, h_schedule: Optional[HSchedule] = None
                  ) -> SyncStrategy:
    """Build the strategy the ``DiLoCoConfig`` knobs describe (registry
    lookup — see ``register_strategy``)."""
    factory = _STRATEGY_REGISTRY.get(cfg.strategy)
    if factory is None:
        raise ValueError(f"unknown strategy {cfg.strategy!r}; "
                         f"expected one of {strategy_names()}")
    return factory(cfg, h_schedule)
