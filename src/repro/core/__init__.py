"""The paper's primary contribution: DiLoCo inner-outer low-communication
training as a composable wrapper over any JAX train step, plus the DDP
baseline, H-schedules (incl. adaptive), drift diagnostics, and compressed
outer synchronization."""
from repro.core.diloco import DiLoCoState, DiLoCoTrainer, run_diloco
from repro.core.ddp import DDPState, DDPTrainer, run_ddp
from repro.core.schedule import AdaptiveH, FixedH, StagedH
from repro.core.grpo import GRPOTrainer, arith_reward_fn, grpo_loss
from repro.core.streaming import (StreamingDiLoCoTrainer, fragment_masks,
                                  run_streaming_diloco)
from repro.core.sync import (AsyncGossipSync, DDPSync, DiLoCoSync,
                             GossipRound, GossipSync, OverlappedSync,
                             PipelinedSync, StreamingSync, SyncEvent,
                             SyncStrategy, gossip_peers, make_strategy,
                             register_strategy, strategy_names)
from repro.core.transport import (BF16Cast, Codec, F32Passthrough,
                                  Int8Symmetric, OuterPayload, Transport,
                                  make_codec)
from repro.core.dist_trainer import DistTrainer
from repro.core.faults import (FaultEvent, FaultSchedule, FleetTracker,
                               RoundInfo, SimulatedCrash)
from repro.core import drift, outer_opt

__all__ = ["DiLoCoTrainer", "DiLoCoState", "run_diloco", "DDPTrainer",
           "DDPState", "run_ddp", "FixedH", "StagedH", "AdaptiveH", "drift",
           "outer_opt", "GRPOTrainer", "grpo_loss", "arith_reward_fn",
           "StreamingDiLoCoTrainer", "fragment_masks",
           "run_streaming_diloco", "DistTrainer", "SyncStrategy", "SyncEvent",
           "DDPSync", "DiLoCoSync", "StreamingSync", "OverlappedSync",
           "PipelinedSync", "GossipSync", "AsyncGossipSync", "GossipRound",
           "gossip_peers", "register_strategy", "strategy_names",
           "make_strategy", "FaultSchedule", "FaultEvent", "FleetTracker",
           "RoundInfo", "SimulatedCrash", "Codec", "OuterPayload",
           "Transport", "F32Passthrough", "BF16Cast", "Int8Symmetric",
           "make_codec"]
