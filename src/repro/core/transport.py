"""Codec-aware outer-sync transport: what actually crosses the slow link.

Strategies used to hand raw f32 pytrees straight to the averaging code;
this module makes the wire explicit.  A sync round now flows

    delta (f32 pytree, stacked (K, ...) per worker)
      -> Codec.encode   -> OuterPayload (wire-dtype data + scales)
      -> Transport.ship -> the SAME payload, resharded to replicated —
                           on a pod mesh this is the inter-pod all-gather,
                           moving the NARROW dtype on the wire
      -> Codec.decode   -> f32 pytree, averaged by the outer optimizer.

Wire format of an ``OuterPayload``
----------------------------------
* ``data``    — pytree mirroring the delta tree, leaves in the codec's
  wire dtype (f32 / bf16 / int8 / fp8 e4m3 / fp8 e5m2), leading K worker
  dim intact.
* ``scales``  — None, or a pytree of per-tensor-per-worker f32 scales
  shaped ``(K, 1, ..., 1)`` (keepdims over every non-worker axis).  These
  4 bytes/tensor/worker ride along with the payload (negligible next to
  the tensor bytes; schedule accounting ignores them).
* ``kind`` / ``codec`` / ``fragment`` — static routing metadata (what the
  payload is, how to decode it, which fragment slot it belongs to).

What a ``Codec`` must implement
-------------------------------
* ``name`` (wire id), ``width`` (wire bytes/element), ``lossy``;
* ``encode(delta, residual=None, kind=..., fragment=...) ->
  (OuterPayload, new_residual)`` — when ``residual`` is given the codec
  must quantize the error-compensated delta ``e = delta + residual`` and
  return ``e - decode(payload)`` as the new residual (error feedback, so
  quantization noise cannot bias the outer optimizer: every bit that
  fails to cross the wire this round is retried next round);
* ``decode(payload) -> f32 pytree``.

``Int8Symmetric`` is backed by the fused Pallas kernels in
``repro.kernels.quantize`` (quantize+residual-update in one pass);
``use_kernel=False`` selects the pure-jnp oracle — the transport does
that automatically on mesh paths, where a Pallas call inside the sharded
outer step would have to partition by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

# wire width (bytes/element) per codec name — the single source of truth
# for every byte-accounting path (schedules, simulator, benchmarks)
WIRE_WIDTH = {"f32": 4, "bf16": 2, "int8": 1, "fp8": 1, "fp8_e5m2": 1}

# config spellings -> canonical codec names ("fp8" is the e4m3 flavor —
# more mantissa, the right trade for error-fed deltas; e5m2 trades it
# back for range)
_ALIASES = {"float32": "f32", "f32": "f32",
            "bfloat16": "bf16", "bf16": "bf16",
            "int8": "int8",
            "fp8": "fp8", "float8": "fp8", "e4m3": "fp8",
            "fp8_e4m3": "fp8",
            "e5m2": "fp8_e5m2", "fp8_e5m2": "fp8_e5m2"}

# codec name -> (wire dtype, bitcast carrier) for Transport.ship's
# narrow-dtype games: the payload crosses the replicate hop as the
# carrier integer type so XLA cannot widen the wire
_WIRE_BITCAST = {"bf16": ("bfloat16", "uint16"),
                 "fp8": ("float8_e4m3fn", "uint8"),
                 "fp8_e5m2": ("float8_e5m2", "uint8")}


@dataclasses.dataclass
class OuterPayload:
    """One encoded cross-worker payload (see module docstring wire format)."""
    data: Any
    scales: Optional[Any] = None
    kind: str = "delta"            # "delta" | "fragment" | "grads"
    codec: str = "f32"
    fragment: int = -1

    def nbytes(self) -> int:
        """Wire bytes per worker-row set: tensor payload + scale sideband."""
        n = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.data))
        if self.scales is not None:
            n += sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(self.scales))
        return int(n)


jax.tree_util.register_dataclass(
    OuterPayload, data_fields=["data", "scales"],
    meta_fields=["kind", "codec", "fragment"])


class Codec:
    """Base codec: lossless identity semantics, subclasses override
    ``_enc`` / ``_dec`` (and optionally ``encode`` for fused paths)."""
    name = "f32"
    lossy = False

    @property
    def width(self) -> int:
        """Wire bytes per element (from the shared ``WIRE_WIDTH`` table)."""
        return WIRE_WIDTH[self.name]

    def _enc(self, e) -> Tuple[Any, Optional[Any]]:
        return e, None

    def _dec(self, data, scales) -> Any:
        return jax.tree.map(lambda p: p.astype(jnp.float32), data)

    def encode(self, delta, residual=None, kind: str = "delta",
               fragment: int = -1) -> Tuple[OuterPayload, Optional[Any]]:
        e = (delta if residual is None else
             jax.tree.map(lambda d, r: d.astype(jnp.float32) + r,
                          delta, residual))
        data, scales = self._enc(e)
        payload = OuterPayload(data=data, scales=scales, kind=kind,
                               codec=self.name, fragment=fragment)
        new_residual = None
        if residual is not None:
            dq = self._dec(data, scales)
            new_residual = jax.tree.map(lambda x, y: x - y, e, dq)
        return payload, new_residual

    def decode(self, payload: OuterPayload) -> Any:
        return self._dec(payload.data, payload.scales)

    def schedule_bytes(self, n_elems: int) -> int:
        """Wire bytes for ``n_elems`` payload elements (per worker)."""
        return self.width * n_elems


@dataclasses.dataclass(frozen=True)
class F32Passthrough(Codec):
    name = "f32"
    lossy = False


@dataclasses.dataclass(frozen=True)
class BF16Cast(Codec):
    """Round-to-nearest-even bf16 cast; exact on bf16-representable values.
    Lossy in general, so error feedback applies when a residual is carried."""
    name = "bf16"
    lossy = True

    def _enc(self, e):
        return jax.tree.map(lambda d: d.astype(jnp.bfloat16), e), None


@dataclasses.dataclass(frozen=True)
class QuantizedCodec(Codec):
    """Shared machinery for symmetric narrow-dtype codecs: q = e / s
    (rounded for int targets), s = amax / QMAX, per-tensor-per-worker.

    With a residual, encode runs the FUSED quantize+residual-update Pallas
    kernel (one pass produces q, new_residual, and the scales); without,
    the same kernel runs and the residual output is dropped.
    ``use_kernel=False`` selects the pure-jnp oracle instead.  Subclasses
    pick the target via ``qdtype`` (a ``kernels.quantize`` target name).
    """
    lossy = True
    use_kernel: bool = True

    @property
    def qdtype(self) -> str:
        return "int8"

    def _quant(self, e, residual):
        # residual leaves may be None (no error feedback): tree.map flattens
        # up to e's structure, so a None in a leaf slot passes through
        qd = self.qdtype
        if self.use_kernel:
            from repro.kernels.quantize import quantize_ef
            return jax.tree.map(lambda d, r: quantize_ef(d, r, dtype=qd),
                                e, residual)
        from repro.kernels.quantize import reference_quantize_ef
        return jax.tree.map(
            lambda d, r: reference_quantize_ef(d, r, dtype=qd), e, residual)

    def encode(self, delta, residual=None, kind: str = "delta",
               fragment: int = -1):
        # the kernel consumes (delta, residual) directly — e = d + r is
        # formed inside the fused pass, not materialized here
        res_tree = (residual if residual is not None
                    else jax.tree.map(lambda _: None, delta))
        out = self._quant(delta, res_tree)
        is3 = lambda x: isinstance(x, tuple)
        q = jax.tree.map(lambda o: o[0], out, is_leaf=is3)
        nr = jax.tree.map(lambda o: o[1], out, is_leaf=is3)
        scales = jax.tree.map(lambda o: o[2], out, is_leaf=is3)
        payload = OuterPayload(data=q, scales=scales, kind=kind,
                               codec=self.name, fragment=fragment)
        return payload, (nr if residual is not None else None)

    def _dec(self, data, scales):
        if self.use_kernel:
            from repro.kernels.quantize import dequantize
            return jax.tree.map(dequantize, data, scales)
        from repro.kernels.quantize import reference_dequantize
        return jax.tree.map(reference_dequantize, data, scales)


@dataclasses.dataclass(frozen=True)
class Int8Symmetric(QuantizedCodec):
    """Per-tensor-per-worker symmetric int8: q = round(e / s), s = amax/127."""
    name = "int8"


@dataclasses.dataclass(frozen=True)
class Fp8Codec(QuantizedCodec):
    """Per-tensor-per-worker scaled fp8 cast: q = cast(e / s), s = amax/QMAX.

    ``flavor`` picks the element type: "e4m3" (default — 3 mantissa bits,
    the Streaming-DiLoCo "outer gradients survive fp8" regime) or "e5m2"
    (2 mantissa bits, wider exponent).  Values are clipped to ±QMAX before
    the cast: e4m3fn has no inf encoding, so an unclipped overflow would
    reach the wire as NaN.
    """
    flavor: str = "e4m3"

    @property
    def name(self) -> str:                  # type: ignore[override]
        return "fp8" if self.flavor == "e4m3" else "fp8_e5m2"

    @property
    def qdtype(self) -> str:
        return "fp8_e4m3" if self.flavor == "e4m3" else "fp8_e5m2"


def make_codec(dtype: str, use_kernel: bool = True) -> Codec:
    """Codec for a config ``delta_dtype`` spelling
    (float32/bfloat16/int8/fp8/e5m2 and friends)."""
    name = _ALIASES.get(dtype)
    if name == "f32":
        return F32Passthrough()
    if name == "bf16":
        return BF16Cast()
    if name == "int8":
        return Int8Symmetric(use_kernel=use_kernel)
    if name == "fp8":
        return Fp8Codec(use_kernel=use_kernel, flavor="e4m3")
    if name == "fp8_e5m2":
        return Fp8Codec(use_kernel=use_kernel, flavor="e5m2")
    raise ValueError(f"unknown delta dtype {dtype!r}; "
                     f"expected one of {sorted(_ALIASES)}")


def wire_width(dtype: str) -> int:
    return WIRE_WIDTH[_ALIASES[dtype]]


@dataclasses.dataclass(frozen=True)
class Transport:
    """Codec + the replicate hop: everything between "delta captured" and
    "f32 delta available on every worker"."""
    codec: Codec
    replicate_fn: Optional[Callable] = None

    def ship(self, payload: OuterPayload) -> OuterPayload:
        """Reshard the encoded payload to replicated — the inter-pod
        all-gather on a pod mesh, identity on a single device.

        The narrow-dtype games mirror what ``average_deltas`` did inline:
        bf16 is bitcast to u16 (fp8 flavors to u8) around the exchange and
        every non-f32 payload sits behind an optimization barrier, so XLA
        cannot fold the dequant converts into the gather's producer and
        move full-width f32 on the wire.
        """
        if self.replicate_fn is None:
            return payload
        data = payload.data
        cast = _WIRE_BITCAST.get(payload.codec)
        if cast is not None:
            carrier = jnp.dtype(cast[1])
            data = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, carrier), data)
        if payload.codec != "f32":
            data = jax.lax.optimization_barrier(data)
        data = self.replicate_fn(data)
        if cast is not None:
            wire = jnp.dtype(cast[0])
            data = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, wire), data)
        scales = payload.scales
        if scales is not None:
            scales = self.replicate_fn(scales)
        return dataclasses.replace(payload, data=data, scales=scales)

    def exchange(self, stacked_delta, residual=None, kind: str = "delta",
                 fragment: int = -1) -> Tuple[Any, Optional[Any]]:
        """encode -> ship -> decode; returns (f32 stacked delta, new
        error-feedback residual or None)."""
        payload, new_residual = self.codec.encode(
            stacked_delta, residual, kind=kind, fragment=fragment)
        payload = self.ship(payload)
        return self.codec.decode(payload), new_residual

    def ship_peers(self, payload: OuterPayload, peer_idx) -> OuterPayload:
        """The gossip hop: worker i receives ONLY row ``peer_idx[i]`` of the
        stacked payload — one peer payload per worker instead of the
        (K-1)-row replicate gather, which is what makes gossip O(1) in
        fleet size.

        The row gather runs on the ENCODED data in the wire dtype (same
        bitcast-carrier + optimization-barrier games as ``ship``), so the
        narrow bytes are what cross the link.  On a pod mesh this hop
        lowers to a ``ppermute`` along the worker axis (a named follow-up);
        the single-device simulation gathers rows locally.
        """
        data = payload.data
        cast = _WIRE_BITCAST.get(payload.codec)
        if cast is not None:
            carrier = jnp.dtype(cast[1])
            data = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, carrier), data)
        if payload.codec != "f32":
            data = jax.lax.optimization_barrier(data)
        data = jax.tree.map(lambda x: x[peer_idx], data)
        if self.replicate_fn is not None:
            data = self.replicate_fn(data)
        if cast is not None:
            wire = jnp.dtype(cast[0])
            data = jax.tree.map(
                lambda x: jax.lax.bitcast_convert_type(x, wire), data)
        scales = payload.scales
        if scales is not None:
            scales = jax.tree.map(lambda s: s[peer_idx], scales)
            if self.replicate_fn is not None:
                scales = self.replicate_fn(scales)
        return dataclasses.replace(payload, data=data, scales=scales)

    def exchange_peers(self, stacked_delta, peer_idx, residual=None,
                       kind: str = "delta", fragment: int = -1
                       ) -> Tuple[Any, Any, Optional[Any]]:
        """Peer-pair exchange: encode -> ship one peer row per worker ->
        decode.  Returns ``(dq_own, dq_peer, new_residual)`` where
        ``dq_own[i]`` is worker i's own decoded delta and ``dq_peer[i]``
        is worker ``peer_idx[i]``'s.  ``peer_idx`` is a dynamic (K,) int32
        array, so a changing matching (random topology) never retraces."""
        payload, new_residual = self.codec.encode(
            stacked_delta, residual, kind=kind, fragment=fragment)
        peer_payload = self.ship_peers(payload, peer_idx)
        return (self.codec.decode(payload), self.codec.decode(peer_payload),
                new_residual)
