"""Standard synchronous data-parallel baseline (the paper's "Standard DDP").

One set of parameters, gradients averaged over the full global batch every
step — exactly nanochat's released pipeline.  On the production mesh the
gradient all-reduce spans ``("pod", "data")``; in simulation it is a plain
mean over the concatenated worker batches, which is mathematically identical
to torch DDP with k processes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import apply_updates, nanochat_optimizer


class DDPState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class DDPTrainer:
    loss_fn: Callable
    opt_cfg: OptimizerConfig

    def init(self, params) -> DDPState:
        opt = nanochat_optimizer(self.opt_cfg)
        return DDPState(params=params, opt=opt.init(params),
                        step=jnp.zeros((), jnp.int32))

    def train_step(self, state: DDPState, batch) -> Tuple[DDPState, jax.Array, Dict]:
        opt = nanochat_optimizer(self.opt_cfg)
        (loss, metrics), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt, state.params,
                                        state.step)
        return (DDPState(apply_updates(state.params, updates), opt_state,
                         state.step + 1), loss, metrics)


def run_ddp(trainer: DDPTrainer, state: DDPState, data_fn, num_steps: int,
            record_every: int = 1, eval_fn: Optional[Callable] = None,
            eval_every: int = 0) -> Tuple[DDPState, Dict]:
    """data_fn(step) -> merged global batch (no worker dim)."""
    step_jit = jax.jit(trainer.train_step)
    history: Dict[str, list] = {"step": [], "loss": [], "evals": []}
    for step in range(num_steps):
        state, loss, _ = step_jit(state, data_fn(step))
        if step % record_every == 0:
            history["step"].append(step)
            history["loss"].append(float(loss))
        if eval_fn is not None and eval_every and (step + 1) % eval_every == 0:
            history["evals"].append((step, eval_fn(state.params)))
    return state, history
