"""Standard synchronous data-parallel baseline (the paper's "Standard DDP").

One set of parameters, gradients averaged over the full global batch every
step — exactly nanochat's released pipeline.  On the production mesh the
gradient all-reduce spans ``("pod", "data")``; in simulation it is a plain
mean over the concatenated worker batches, which is mathematically identical
to torch DDP with k processes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.optim import apply_updates, nanochat_optimizer


class DDPState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class DDPTrainer:
    loss_fn: Callable
    opt_cfg: OptimizerConfig

    def init(self, params) -> DDPState:
        opt = nanochat_optimizer(self.opt_cfg)
        return DDPState(params=params, opt=opt.init(params),
                        step=jnp.zeros((), jnp.int32))

    def train_step(self, state: DDPState, batch) -> Tuple[DDPState, jax.Array, Dict]:
        opt = nanochat_optimizer(self.opt_cfg)
        (loss, metrics), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt, state.params,
                                        state.step)
        return (DDPState(apply_updates(state.params, updates), opt_state,
                         state.step + 1), loss, metrics)


def run_ddp(trainer: DDPTrainer, state: DDPState, data_fn, num_steps: int,
            record_every: int = 1, eval_fn: Optional[Callable] = None,
            eval_every: int = 0) -> Tuple[DDPState, Dict]:
    """data_fn(step) -> merged global batch (no worker dim).

    Thin wrapper over the unified ``DistTrainer`` runtime: DDP is the K=1
    strategy on the global batch, so the ``DDPState`` is lifted into the
    stacked worker encoding, run under ``DDPSync``, and lowered back.
    """
    from repro.configs.base import DiLoCoConfig
    from repro.core import outer_opt
    from repro.core.diloco import DiLoCoState
    from repro.core.dist_trainer import DistTrainer
    from repro.core.sync import DDPSync

    dcfg = DiLoCoConfig(num_workers=1, h_inner_steps=1, outer_lr=1.0,
                        outer_momentum=0.0, nesterov=False, strategy="ddp")
    dt = DistTrainer(trainer.loss_fn, trainer.opt_cfg, dcfg, DDPSync())
    lifted = DiLoCoState(
        global_params=state.params,
        outer=outer_opt.init_outer_state(state.params),
        worker_params=jax.tree.map(lambda x: x[None], state.params),
        inner_opt=jax.tree.map(lambda x: jnp.asarray(x)[None], state.opt),
        inner_step=state.step)
    lifted, history = dt.run(
        lifted, lambda s: jax.tree.map(lambda x: x[None], data_fn(s)),
        num_steps, record_every=record_every, eval_fn=eval_fn,
        eval_every=eval_every)
    final = DDPState(
        params=jax.tree.map(lambda x: x[0], lifted.worker_params),
        opt=jax.tree.map(lambda x: x[0], lifted.inner_opt),
        step=lifted.inner_step)
    return final, history
