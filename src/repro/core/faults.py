"""Deterministic fault injection for the elastic training runtime.

The paper's setting — communication-constrained, decentralized fleets — is
exactly where workers are preemptible and links flake, yet a scripted
failure is the only kind a CI box can *reproduce*.  This module makes
failure a first-class, bit-exactly replayable event:

* ``FaultSchedule`` — an immutable script of per-worker events
  (crash-at-step, rejoin-at-step, slowdown factor, dropped/corrupted
  outer payload) plus run-level ``kill`` events (the whole process dies,
  the crash-consistency anchor for ``--resume``).  Schedules load from
  JSON files or a compact inline spec
  (``"crash:2@10,rejoin:2@20,slow:1@5x1.5,drop:3@9x2,kill@30"``) and can
  be drawn from a seeded RNG (``FaultSchedule.random``) — either way the
  event list is data, so any box replays the same failures.
* ``FleetTracker`` — the host-side state machine ``DistTrainer.run`` and
  the sync runners consult: per-worker liveness, pending rejoins, the
  per-round contribution/adoption/reset masks (the ``(K,)`` arrays the
  quorum outer-sync jits take — fixed signatures, a changing live-set
  never retraces), the ``min_quorum`` skip rule, and the one-retry
  accounting for dropped payloads.
* ``SimulatedCrash`` — raised by the trainer after a ``kill`` event's
  step completes (and after any due checkpoint is written), so the
  crash/resume tests exercise the same code path a real SIGKILL would
  leave behind.

Semantics (all step indices are inner-step indices):

* ``crash w@s``  — worker w executes steps ``< s`` only; from step s its
  row is frozen (masked out of inner chunks) and it neither contributes
  to nor adopts outer rounds.
* ``rejoin w@s`` — at the first outer boundary ``>= s`` the worker
  re-enters by adopting the current anchor with zeroed inner-optimizer
  and error-feedback state; ``core.drift`` metrics are logged at the
  adoption so the drift cost of churn is measurable.
* ``slow w@s xF`` — from step s, worker w's modeled step time is
  multiplied by F.  Training math is unchanged (the simulation is
  synchronous); the comm simulator consumes it for wall-clock.
* ``drop/corrupt w@s [xN]`` — worker w's outer payload at the sync
  boundary at step s fails N times (default 1).  One codec-aware retry
  is attempted; with N >= 2 the retry also fails and the worker is
  counted out of THAT round's average (it still adopts the result — its
  downlink is fine).
* ``kill@s``     — the whole run raises ``SimulatedCrash`` after step s.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random as _pyrandom
from typing import Dict, List, Optional, Sequence, Tuple

FAULT_KINDS = ("crash", "rejoin", "slow", "drop", "corrupt", "kill")

# events a runner resolves at an outer boundary (vs. trainer chunk gating)
_PAYLOAD_KINDS = ("drop", "corrupt")


class SimulatedCrash(RuntimeError):
    """Raised by ``DistTrainer.run`` when a scripted ``kill`` event fires —
    after the step's bookkeeping (and any due checkpoint) completes, so a
    catcher observes exactly what a process kill would leave on disk."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted failure.  ``worker`` is -1 for run-level ``kill``;
    ``factor`` is the slowdown multiplier for ``slow``; ``attempts`` is
    how many consecutive sends fail for ``drop``/``corrupt`` (1 = the
    retry succeeds, >= 2 = counted out of the round)."""
    step: int
    kind: str
    worker: int = -1
    factor: float = 1.0
    attempts: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind != "kill" and self.worker < 0:
            raise ValueError(f"{self.kind} event needs a worker index")
        if self.kind == "slow" and self.factor <= 0:
            raise ValueError(f"slow factor must be > 0, got {self.factor}")
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, order-independent script of ``FaultEvent``s."""
    events: Tuple[FaultEvent, ...] = ()

    # -- construction --------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "FaultSchedule":
        """Parse the compact inline DSL: comma-separated
        ``kind:worker@step[xFACTOR]`` items (``kill@step`` has no worker).
        Examples: ``crash:2@10``, ``rejoin:2@20``, ``slow:1@5x1.5``,
        ``drop:3@9x2`` (two failed attempts — counted out), ``kill@30``.
        A path ending in ``.json`` loads the JSON file instead."""
        spec = spec.strip()
        if not spec:
            return cls()
        if spec.endswith(".json") or os.path.sep in spec:
            return cls.load(spec)
        events = []
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            kind, _, rest = item.partition(":")
            kind = kind.strip()
            if kind.partition("@")[0] == "kill":
                # kill@step (no worker); kill:@step also tolerated
                at = (rest or kind).partition("@")[2]
                events.append(FaultEvent(step=int(at), kind="kill"))
                continue
            wtxt, _, at = rest.partition("@")
            extra = 1.0
            if "x" in at:
                at, _, xtxt = at.partition("x")
                extra = float(xtxt)
            ev = dict(step=int(at), kind=kind, worker=int(wtxt))
            if kind == "slow":
                ev["factor"] = extra
            elif kind in _PAYLOAD_KINDS:
                ev["attempts"] = max(int(extra), 1)
            events.append(FaultEvent(**ev))
        return cls(tuple(events))

    @classmethod
    def load(cls, path: str) -> "FaultSchedule":
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict):
            data = data.get("events", [])
        return cls(tuple(FaultEvent(**e) for e in data))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"events": [dataclasses.asdict(e)
                                  for e in self.events]}, f, indent=1)

    @classmethod
    def random(cls, k: int, num_steps: int, seed: int,
               crashes: int = 1, rejoin_after: Optional[int] = None
               ) -> "FaultSchedule":
        """A seeded crash/rejoin scenario: ``crashes`` distinct workers
        crash at seeded steps; the first crashed worker rejoins
        ``rejoin_after`` steps later (None = never).  Pure function of
        the arguments — the draw IS the script, so it replays anywhere."""
        rng = _pyrandom.Random(seed)
        workers = rng.sample(range(k), min(crashes, k))
        events = []
        for i, w in enumerate(workers):
            s = rng.randrange(1, max(num_steps - 1, 2))
            events.append(FaultEvent(step=s, kind="crash", worker=w))
            if i == 0 and rejoin_after is not None:
                events.append(FaultEvent(
                    step=min(s + rejoin_after, num_steps - 1),
                    kind="rejoin", worker=w))
        return cls(tuple(sorted(events, key=lambda e: e.step)))

    # -- queries -------------------------------------------------------------
    @property
    def empty(self) -> bool:
        return not self.events

    def worker_events(self) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind != "kill")

    def validate(self, k: int) -> None:
        for e in self.events:
            if e.kind != "kill" and not 0 <= e.worker < k:
                raise ValueError(
                    f"fault event {e} names worker {e.worker} outside the "
                    f"fleet (num_workers={k})")

    def chunk_limit(self, step: int) -> Optional[int]:
        """Last step a chunk starting at ``step`` may include: a chunk
        must end BEFORE a crash (the mask changes at the crash step) and
        AT a kill (the process dies after it)."""
        lim = None

        def take(x):
            nonlocal lim
            lim = x if lim is None else min(lim, x)

        for e in self.events:
            if e.kind == "crash" and e.step > step:
                take(e.step - 1)
            elif e.kind == "kill" and e.step >= step:
                take(e.step)
        return lim


@dataclasses.dataclass
class RoundInfo:
    """Masks for one quorum outer round (all length-K bool tuples).

    ``contrib`` — rows averaged this round (live, payload survived);
    ``adopt``   — rows that take the round's result (live workers incl.
                  dropped-payload ones — their downlink works);
    ``reset``   — rejoiners: adopt AND restart inner/EF state from zero;
    ``live``    — alive after this round (adopt ∪ reset);
    ``skip``    — quorum not met: no averaging, rejoiners still adopt;
    ``retries`` — payload resends attempted this round (byte accounting);
    ``records`` — history records describing the round's fault activity.
    """
    contrib: Tuple[bool, ...]
    adopt: Tuple[bool, ...]
    reset: Tuple[bool, ...]
    live: Tuple[bool, ...]
    skip: bool
    retries: int
    records: List


class FleetTracker:
    """Host-side fleet state: consumes a ``FaultSchedule`` as the trainer
    advances.  All decisions are pure functions of (schedule, k,
    min_quorum, step) — the tracker only caches them — so replays are
    bit-exact by construction."""

    def __init__(self, schedule: FaultSchedule, k: int, min_quorum: int = 1):
        schedule.validate(k)
        if not 1 <= min_quorum <= k:
            raise ValueError(f"min_quorum must be in [1, {k}], "
                             f"got {min_quorum}")
        self.schedule = schedule
        self.k = k
        self.min_quorum = min_quorum
        self.live: List[bool] = [True] * k
        # worker -> rejoin step, applied at the next outer boundary >= it
        self.pending_rejoin: Dict[int, int] = {}
        self._crash_done: set = set()
        self._rejoin_done: set = set()
        self.quorum_log: List[Tuple[int, int]] = []  # (step, contributors)

    # -- trainer-facing ------------------------------------------------------
    def chunk_limit(self, step: int) -> Optional[int]:
        return self.schedule.chunk_limit(step)

    def kill_at(self, step: int) -> bool:
        return any(e.kind == "kill" and e.step == step
                   for e in self.schedule.events)

    def begin_chunk(self, step: int) -> Tuple[Tuple[bool, ...], List]:
        """Apply crash (and queue rejoin/slow) events with
        ``event.step <= step``; returns (live mask for the chunk,
        history records for newly-fired events)."""
        records: List = []
        for i, e in enumerate(self.schedule.events):
            if e.step > step or i in self._crash_done:
                continue
            if e.kind == "crash":
                self._crash_done.add(i)
                if self.live[e.worker]:
                    self.live[e.worker] = False
                    self.pending_rejoin.pop(e.worker, None)
                    records.append(("fault", (e.step, "crash", e.worker)))
            elif e.kind == "rejoin":
                self._crash_done.add(i)
                if not self.live[e.worker] and e.worker not in self.pending_rejoin:
                    self.pending_rejoin[e.worker] = e.step
                    records.append(("fault", (e.step, "rejoin_pending",
                                              e.worker)))
            elif e.kind == "slow":
                self._crash_done.add(i)
                records.append(("fault", (e.step, "slow", e.worker,
                                          e.factor)))
        return tuple(self.live), records

    def catch_up(self, step: int) -> None:
        """Fast-forward fleet state to a resume point: crashes strictly
        before ``step`` have happened, and rejoins strictly before
        ``step`` are treated as already adopted (resume checkpoints are
        written at outer boundaries, after pending rejoins land)."""
        if step <= 0:
            return
        self.begin_chunk(step - 1)
        for w, s in list(self.pending_rejoin.items()):
            if s < step:
                self.live[w] = True
                del self.pending_rejoin[w]

    @property
    def all_live(self) -> bool:
        return all(self.live) and not self.pending_rejoin

    # -- runner-facing -------------------------------------------------------
    def round_masks(self, step: int) -> RoundInfo:
        """Masks for the outer round at boundary ``step``.  Mutates the
        tracker (rejoiners become live) — call exactly once per boundary,
        which the chunked loop guarantees (a boundary is a chunk end and
        ``after_step`` replays each step once)."""
        records: List = []
        k = self.k
        # queue rejoins due by this boundary straight from the schedule:
        # a rejoin step landing MID-chunk never starts a chunk of its own
        # (chunks split at crashes and kills only), so ``begin_chunk``
        # alone would miss it until the next chunk — too late for the
        # boundary that should apply it
        for i, e in enumerate(self.schedule.events):
            if e.kind != "rejoin" or e.step > step \
                    or i in self._crash_done:
                continue
            self._crash_done.add(i)
            if not self.live[e.worker] \
                    and e.worker not in self.pending_rejoin:
                self.pending_rejoin[e.worker] = e.step
                records.append(("fault", (e.step, "rejoin_pending",
                                          e.worker)))
        contrib = list(self.live)
        retries = 0
        for e in self.schedule.events:
            if e.step != step or e.kind not in _PAYLOAD_KINDS:
                continue
            if not self.live[e.worker]:
                continue        # a dead worker ships nothing to drop
            retries += 1        # the one codec-aware retry is attempted
            if e.attempts >= 2:
                contrib[e.worker] = False   # retry failed too: counted out
                records.append(("fault", (step, e.kind + "_lost", e.worker)))
            else:
                records.append(("fault", (step, e.kind + "_retry", e.worker)))
        reset = [False] * k
        for w, s in sorted(self.pending_rejoin.items()):
            if s <= step:
                reset[w] = True
                self.live[w] = True
                del self.pending_rejoin[w]
                records.append(("fault", (step, "rejoin", w)))
        adopt = list(self.live)
        for w in range(k):
            if reset[w]:
                adopt[w] = False   # rejoiners adopt via the reset path
        n_contrib = sum(contrib)
        skip = n_contrib < self.min_quorum
        self.quorum_log.append((step, n_contrib))
        records.append(("quorum", (step, n_contrib)))
        if skip:
            records.append(("quorum_skip", step))
        return RoundInfo(contrib=tuple(contrib), adopt=tuple(adopt),
                         reset=tuple(reset), live=tuple(self.live),
                         skip=skip, retries=retries, records=records)


# ---------------------------------------------------------------------------
# Comm-simulator view: per-worker wall-clock effects of the same script
# ---------------------------------------------------------------------------

def sim_timeline(schedule: FaultSchedule, k: int, num_steps: int
                 ) -> Tuple[List[List[bool]], List[List[float]],
                            Dict[int, List[int]]]:
    """Expand the schedule into per-step per-worker (alive, speed-factor)
    tables plus ``failed_sends[step] -> [workers whose payload is lost
    even after the retry]`` — the form the wall-clock simulators consume.
    Pure function of the script; the training-side ``FleetTracker`` and
    this expansion agree on liveness by construction (same event rules).
    """
    schedule.validate(k)
    alive = [True] * k
    factor = [1.0] * k
    alive_t: List[List[bool]] = []
    factor_t: List[List[float]] = []
    failed: Dict[int, List[int]] = {}
    by_step: Dict[int, List[FaultEvent]] = {}
    for e in schedule.events:
        by_step.setdefault(e.step, []).append(e)
    for s in range(num_steps):
        for e in by_step.get(s, ()):
            if e.kind == "crash":
                alive[e.worker] = False
            elif e.kind == "rejoin":
                alive[e.worker] = True
            elif e.kind == "slow":
                factor[e.worker] = e.factor
            elif e.kind in _PAYLOAD_KINDS and e.attempts >= 2:
                failed.setdefault(s, []).append(e.worker)
        alive_t.append(list(alive))
        factor_t.append(list(factor))
    return alive_t, factor_t, failed


def retry_counts(schedule: FaultSchedule, num_steps: int) -> Dict[int, int]:
    """step -> number of payload retries shipped at that step (every
    drop/corrupt event triggers exactly one resend attempt)."""
    out: Dict[int, int] = {}
    for e in schedule.events:
        if e.kind in _PAYLOAD_KINDS and e.step < num_steps:
            out[e.step] = out.get(e.step, 0) + 1
    return out
