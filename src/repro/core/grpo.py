"""Simplified GRPO — nanochat's optional final stage (reward-model-free
preference optimization on GSM8K), reproduced on the synthetic arithmetic
task.

Per prompt, sample G completions on-policy, score them with a programmatic
reward (exact-match), normalize advantages within the group
(A_i = (r_i − mean r) / (std r + ε)), and take a policy-gradient step

    L = − E[ A_i · log π(completion_i | prompt) ]

(no ratio/clipping — single-step on-policy, as in nanochat's simplified
GRPO).  Works with any trainer params; DiLoCo-wrapped GRPO is just this
loss handed to DiLoCoTrainer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.models.transformer import ModelAPI
from repro.optim import apply_updates, nanochat_optimizer
from repro.serving.engine import Engine


def grpo_loss(params, batch, model: ModelAPI):
    """batch: tokens (B,T), labels (B,T) (-1 outside the completion),
    adv (B,).  Returns (loss, metrics)."""
    logits, _ = model.forward(params, {"tokens": batch["tokens"]})
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    labels = batch["labels"]
    gold = jnp.take_along_axis(lp, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    seq_logprob = jnp.sum(gold * valid, axis=1)
    tokens_per_seq = jnp.maximum(jnp.sum(valid, axis=1), 1.0)
    loss = -jnp.mean(batch["adv"] * seq_logprob / tokens_per_seq)
    return loss, {"mean_logprob": jnp.mean(seq_logprob / tokens_per_seq)}


@dataclasses.dataclass
class GRPOTrainer:
    model: ModelAPI
    opt_cfg: OptimizerConfig
    group_size: int = 8
    max_new: int = 8
    temperature: float = 1.0

    def init(self, params):
        opt = nanochat_optimizer(self.opt_cfg)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def _update(self, state, batch):
        opt = nanochat_optimizer(self.opt_cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p, b: grpo_loss(p, b, self.model), has_aux=True)(
                state["params"], batch)
        upd, opt_state = opt.update(grads, state["opt"], state["params"],
                                    state["step"])
        return {"params": apply_updates(state["params"], upd),
                "opt": opt_state, "step": state["step"] + 1}, loss

    def rollout_and_step(self, state, prompts: Sequence[Sequence[int]],
                         reward_fn: Callable[[int, np.ndarray], float],
                         pad_id: int, seed: int = 0
                         ) -> Tuple[Dict, float, float]:
        """One GRPO iteration: sample G completions per prompt, reward,
        normalize within group, update.  reward_fn(prompt_idx, token_row)
        -> float.  Returns (state, loss, mean_reward).

        Rollouts go through the continuous-batching scheduler (the same
        serving path as the evals): G×P sampling requests share the slot
        set, each with its own PRNG stream (folded from ``seed`` and the
        request id), so on-policy sampling is deterministic per seed and the
        engine — and its compiled step — is reused across iterations."""
        if not hasattr(self, "_engine") or self._engine.model is not self.model:
            self._engine = Engine(self.model, state["params"])
        engine = self._engine
        engine.params = state["params"]     # jitted steps take params as args
        G = self.group_size
        rep_prompts = [p for p in prompts for _ in range(G)]
        out = engine.generate_ids(rep_prompts, max_new=self.max_new,
                                  greedy=False,
                                  temperature=self.temperature, seed=seed)
        rewards = np.asarray([
            reward_fn(i // G, out[i]) for i in range(len(rep_prompts))],
            np.float32)
        adv = rewards.reshape(len(prompts), G)
        adv = (adv - adv.mean(axis=1, keepdims=True)) / (
            adv.std(axis=1, keepdims=True) + 1e-6)
        adv = adv.reshape(-1)

        tmax = max(len(p) for p in rep_prompts) + self.max_new
        toks = np.full((len(rep_prompts), tmax), pad_id, np.int32)
        labels = np.full((len(rep_prompts), tmax), -1, np.int32)
        for i, p in enumerate(rep_prompts):
            seq = list(p) + list(out[i])
            toks[i, :len(seq)] = seq
            # predict completion tokens: positions len(p)-1 .. len(seq)-2
            labels[i, len(p) - 1:len(seq) - 1] = out[i]
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 "adv": jnp.asarray(adv)}
        if not hasattr(self, "_update_jit"):
            self._update_jit = jax.jit(self._update)
        state, loss = self._update_jit(state, batch)
        return state, float(loss), float(rewards.mean())


def arith_reward_fn(tok, items: List[dict]) -> Callable:
    """Reward = 1 if the decoded completion starts with the gold answer."""
    def fn(prompt_idx: int, row: np.ndarray) -> float:
        text = tok.decode(list(row)).strip()
        return 1.0 if text.startswith(items[prompt_idx]["answer"]) else 0.0
    return fn
